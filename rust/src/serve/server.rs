//! The TCP front-end: thread-per-connection over the length-prefixed
//! protocol, answering every query from the current snapshot epoch.
//!
//! std-only by design (the offline build carries no async runtime), and
//! consistent with the crate's substrate: a connection is a real
//! preemptively-scheduled execution unit, like a worker. Queries touch the
//! service only through [`VqService::snapshot`]/[`VqService::ingest`], so
//! a slow client can never hold a lock the reducer or another reader
//! needs.

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::obs::TelemetrySnapshot;

use super::batch::Batcher;
use super::protocol::{
    read_frame, write_frame, MetricEvent, MetricHist, MetricsReply, Request,
    Response, StatsReply, MAX_FRAME,
};
use super::service::{TimedQuery, VqService};

/// A running TCP front-end over a [`VqService`].
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    service: Arc<VqService>,
    /// The cross-request coalescer — `Some` only when the serve config
    /// arms `batch_window_us` (default off = the direct scan path).
    batcher: Option<Arc<Batcher>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting connections against `service`.
    pub fn start(service: Arc<VqService>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding serve front-end to {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let batcher = if service.batch_window_us() > 0 {
            Some(Batcher::start(Arc::clone(&service)))
        } else {
            None
        };
        let accept = {
            let stop = Arc::clone(&stop);
            let service = Arc::clone(&service);
            let batcher = batcher.clone();
            std::thread::Builder::new()
                .name("dalvq-serve-accept".into())
                .spawn(move || accept_loop(listener, service, batcher, stop))
                .expect("spawning accept thread")
        };
        Ok(Server { addr: local, stop, accept: Some(accept), service, batcher })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The service behind this front-end.
    pub fn service(&self) -> &Arc<VqService> {
        &self.service
    }

    /// Stop accepting. Existing connections finish on their own threads
    /// and exit at client hang-up.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::Release);
        // Unblock the accept() call with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept.take() {
            j.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        // Stop the coalescer after the front door: queued requests are
        // still answered, and stragglers on connections that outlive the
        // server fall back to the direct scan path.
        if let Some(b) = &self.batcher {
            b.shutdown();
        }
        Ok(())
    }
}

fn accept_loop(
    listener: TcpListener,
    service: Arc<VqService>,
    batcher: Option<Arc<Batcher>>,
    stop: Arc<AtomicBool>,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            return;
        }
        let Ok(stream) = conn else { continue };
        let service = Arc::clone(&service);
        let batcher = batcher.clone();
        let _ = std::thread::Builder::new()
            .name("dalvq-serve-conn".into())
            .spawn(move || {
                let _ = serve_connection(stream, &service, batcher.as_deref());
            });
    }
}

/// One connection: frames in, frames out, until the peer hangs up.
fn serve_connection(
    stream: TcpStream,
    service: &VqService,
    batcher: Option<&Batcher>,
) -> Result<()> {
    stream.set_nodelay(true).ok(); // request/response pattern
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    while let Some(payload) = read_frame(&mut reader)? {
        let t_decode = Instant::now();
        let decoded = Request::decode(&payload);
        service
            .tel()
            .decode_us
            .record(t_decode.elapsed().as_micros() as u64);
        let resp = match decoded {
            Ok(req) => handle(service, batcher, req),
            Err(e) => Response::Error { message: format!("{e:#}") },
        };
        let t_encode = Instant::now();
        let bytes = resp.encode();
        service
            .tel()
            .encode_us
            .record(t_encode.elapsed().as_micros() as u64);
        write_frame(&mut writer, &bytes)?;
    }
    Ok(())
}

/// Dispatch one request with per-op accounting wrapped around
/// [`dispatch`]: count the request into its op family, time the whole
/// handler into the op's latency histogram, and — when the slow-query
/// log is armed — journal any request over the threshold with whatever
/// stage breakdown the dispatch recorded.
fn handle(
    service: &VqService,
    batcher: Option<&Batcher>,
    req: Request,
) -> Response {
    let tel = service.tel();
    let (op_name, op) = match &req {
        Request::Encode { .. } => ("encode", &tel.op_encode),
        Request::Nearest { .. } => ("nearest", &tel.op_nearest),
        Request::Distortion { .. } => ("distortion", &tel.op_distortion),
        Request::Ingest { .. } => ("ingest", &tel.op_ingest),
        _ => ("other", &tel.op_other),
    };
    op.requests.inc();
    let t0 = Instant::now();
    let mut stages: Option<(u64, u64)> = None;
    let resp = dispatch(service, batcher, req, &mut stages);
    let total_us = t0.elapsed().as_micros() as u64;
    op.total_us.record(total_us);
    let threshold = service.slow_query_us();
    if threshold > 0 && total_us > threshold {
        tel.slow_queries.inc();
        let breakdown = match stages {
            Some((route_us, scan_us)) => {
                format!(", route {route_us} us + scan {scan_us} us")
            }
            None => String::new(),
        };
        service.telemetry().journal().warn(
            "slow_query",
            format!(
                "{op_name} took {total_us} us (threshold {threshold} us, \
                 {} shards{breakdown})",
                service.shards()
            ),
        );
    }
    resp
}

/// Dispatch one request through the service's routed query/ingest surface
/// (multi-probe over the shard fleets happens inside [`VqService`]).
/// Read queries run the timed path and report their (route, scan) µs
/// through `stages` for the slow-query log.
///
/// On a follower, every leader-only op — writes (`Ingest`,
/// `Checkpoint`, `Rebalance`) and state shipping (`FetchState`) —
/// answers `NotLeader` with the leader's address, so a client can
/// redirect instead of parsing an error string. The read surface —
/// `Metrics` included (a follower's telemetry is its own, not the
/// leader's) — is identical on both roles.
fn dispatch(
    service: &VqService,
    batcher: Option<&Batcher>,
    req: Request,
    stages: &mut Option<(u64, u64)>,
) -> Response {
    if matches!(
        req,
        Request::Ingest { .. }
            | Request::Checkpoint
            | Request::Rebalance { .. }
            | Request::FetchState { .. }
    ) {
        if let Some(leader) = service.follower_of() {
            return Response::NotLeader { leader };
        }
    }
    let dim = service.dim();
    let check = |points: &[f32]| -> Option<Response> {
        if points.is_empty() || points.len() % dim != 0 {
            Some(Response::Error {
                message: format!(
                    "batch of {} floats is not a positive multiple of dim {dim}",
                    points.len()
                ),
            })
        } else {
            None
        }
    };
    // Admission: a request small enough to *arrive* can still demand a
    // reply too large to *frame* (at dim 1 a Nearest request of n points
    // is 5 + 4n bytes but its reply is 17 + 8n — past the cap for the
    // top half of the admissible range). Reject those here, before any
    // routing or scan work is spent on an unanswerable query.
    let reply_cap = |op: &str, fixed: usize, per_point: usize, n: usize| {
        let bytes = fixed + per_point * n;
        if bytes > MAX_FRAME as usize {
            Some(Response::Error {
                message: format!(
                    "{op} reply for {n} points would be {bytes} bytes, over \
                     the {MAX_FRAME}-byte frame cap; split the batch",
                ),
            })
        } else {
            None
        }
    };
    let count_query = || {
        service
            .counters()
            .queries
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    };
    match req {
        Request::Encode { points } => {
            if let Some(err) = check(&points) {
                return err;
            }
            // Codes reply: op + version + len prefix + 4 bytes/code.
            if let Some(err) = reply_cap("encode", 13, 4, points.len() / dim) {
                return err;
            }
            count_query();
            let q = run_query(service, batcher, &points);
            *stages = Some((q.route_us, q.scan_us));
            Response::Codes { version: q.version, codes: q.codes }
        }
        Request::Nearest { points } => {
            if let Some(err) = check(&points) {
                return err;
            }
            // Neighbors reply: op + version + two prefixed f32/u32 runs.
            if let Some(err) = reply_cap("nearest", 17, 8, points.len() / dim) {
                return err;
            }
            count_query();
            let q = run_query(service, batcher, &points);
            *stages = Some((q.route_us, q.scan_us));
            Response::Neighbors {
                version: q.version,
                indices: q.codes,
                dists: q.dists,
            }
        }
        Request::Distortion { points } => {
            if let Some(err) = check(&points) {
                return err;
            }
            count_query();
            let q = run_query(service, batcher, &points);
            *stages = Some((q.route_us, q.scan_us));
            // check() rejected empty batches, so dists is never empty.
            let sum: f64 = q.dists.iter().map(|d| *d as f64).sum();
            Response::Distortion {
                version: q.version,
                value: sum / q.dists.len() as f64,
            }
        }
        Request::Ingest { points } => match service.ingest(&points) {
            Ok((accepted, shed)) => Response::IngestAck { accepted, shed },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        Request::Stats => {
            let s = service.stats();
            Response::Stats(StatsReply {
                version: s.version,
                kappa: s.kappa as u64,
                dim: s.dim as u64,
                workers: s.workers as u64,
                shards: s.shards as u64,
                probe_n: s.probe_n as u64,
                router_version: s.router_version,
                rebalances: s.rebalances,
                merges: s.merges,
                ingested: s.ingested,
                ingest_shed: s.ingest_shed,
                queries: s.queries,
                shard_versions: s.shard_versions,
                shard_merges: s.shard_merges,
                shard_ingest: s.shard_ingest,
                shard_shed: s.shard_shed,
                last_checkpoint: s.last_checkpoint,
                state_dir: s.state_dir.unwrap_or_default(),
                role: s.role,
                leader_addr: s.leader_addr.unwrap_or_default(),
                sync_lag_folds: s.sync_lag_folds,
                last_sync: s.last_sync_ms,
                uptime_ms: s.uptime_ms,
                op_encode: s.op_encode,
                op_nearest: s.op_nearest,
                op_distortion: s.op_distortion,
                op_ingest: s.op_ingest,
            })
        }
        Request::Metrics { max_events } => Response::Metrics(metrics_reply(
            service.metrics_snapshot(max_events as usize),
        )),
        Request::Checkpoint => match service.checkpoint_now() {
            Ok(versions) => Response::CheckpointAck { versions },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        // The epoch swap happens entirely inside the service; this
        // connection blocks until the new partition serves, while reads
        // on other connections keep answering from the old epoch.
        Request::Rebalance { want_remap } => match service.rebalance() {
            Ok(out) => Response::RebalanceAck {
                router_version: out.router_version,
                moved_rows: out.moved_rows,
                shard_versions: out.shard_versions,
                remap: if want_remap { out.remap } else { Vec::new() },
            },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        // Replication: ship the durable state as one consistent bundle.
        Request::FetchState { have_generation } => {
            match service.fetch_state(have_generation) {
                Ok(shipment) => Response::State(shipment),
                Err(e) => Response::Error { message: format!("{e:#}") },
            }
        }
    }
}

/// One read batch through the query plane: the coalescer when armed
/// (falling back to the direct path if it is already shut down), else an
/// immediate fused scan on this connection thread. Either route answers
/// bit-identically; only latency and staleness differ.
fn run_query(
    service: &VqService,
    batcher: Option<&Batcher>,
    points: &[f32],
) -> TimedQuery {
    if let Some(b) = batcher {
        if let Some(a) = b.submit(points.to_vec()) {
            return TimedQuery {
                version: a.version,
                codes: a.codes,
                dists: a.dists,
                route_us: a.route_us,
                scan_us: a.scan_us,
            };
        }
    }
    service.query_nearest_timed(points, service.probe_n())
}

/// A telemetry snapshot in wire shape. By value: the snapshot is already
/// this handler's own copy, so the strings and vectors move instead of
/// cloning.
fn metrics_reply(snap: TelemetrySnapshot) -> MetricsReply {
    MetricsReply {
        uptime_ms: snap.uptime_ms,
        counters: snap.counters,
        gauges: snap.gauges,
        hists: snap
            .hists
            .into_iter()
            .map(|(name, s)| MetricHist {
                name,
                count: s.count,
                mean_us: s.mean_us,
                p50_us: s.p50_us,
                p95_us: s.p95_us,
                p99_us: s.p99_us,
                max_us: s.max_us,
            })
            .collect(),
        events: snap
            .events
            .into_iter()
            .map(|e| MetricEvent {
                seq: e.seq,
                ts_ms: e.ts_ms,
                level: e.level.as_u8(),
                kind: e.kind,
                message: e.message,
            })
            .collect(),
    }
}
