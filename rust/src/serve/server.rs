//! The TCP front-end: thread-per-connection over the length-prefixed
//! protocol, answering every query from the current snapshot epoch.
//!
//! std-only by design (the offline build carries no async runtime), and
//! consistent with the crate's substrate: a connection is a real
//! preemptively-scheduled execution unit, like a worker. Queries touch the
//! service only through [`VqService::snapshot`]/[`VqService::ingest`], so
//! a slow client can never hold a lock the reducer or another reader
//! needs.

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::obs::{
    FinishedTrace, SpanRec, TelemetrySnapshot, TraceBuilder, NO_PARENT,
};

use super::batch::Batcher;
use super::protocol::{
    encode_traced_response, read_frame, write_frame, MetricEvent, MetricHist,
    MetricsReply, Request, Response, StatsReply, WireSpan, WireTrace,
    MAX_FRAME,
};
use super::service::{TimedQuery, VqService};

/// A running TCP front-end over a [`VqService`].
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    service: Arc<VqService>,
    /// The cross-request coalescer — `Some` only when the serve config
    /// arms `batch_window_us` (default off = the direct scan path).
    batcher: Option<Arc<Batcher>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting connections against `service`.
    pub fn start(service: Arc<VqService>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding serve front-end to {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let batcher = if service.batch_window_us() > 0 {
            Some(Batcher::start(Arc::clone(&service)))
        } else {
            None
        };
        let accept = {
            let stop = Arc::clone(&stop);
            let service = Arc::clone(&service);
            let batcher = batcher.clone();
            std::thread::Builder::new()
                .name("dalvq-serve-accept".into())
                .spawn(move || accept_loop(listener, service, batcher, stop))
                .expect("spawning accept thread")
        };
        Ok(Server { addr: local, stop, accept: Some(accept), service, batcher })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The service behind this front-end.
    pub fn service(&self) -> &Arc<VqService> {
        &self.service
    }

    /// Stop accepting. Existing connections finish on their own threads
    /// and exit at client hang-up.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::Release);
        // Unblock the accept() call with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept.take() {
            j.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        // Stop the coalescer after the front door: queued requests are
        // still answered, and stragglers on connections that outlive the
        // server fall back to the direct scan path.
        if let Some(b) = &self.batcher {
            b.shutdown();
        }
        Ok(())
    }
}

fn accept_loop(
    listener: TcpListener,
    service: Arc<VqService>,
    batcher: Option<Arc<Batcher>>,
    stop: Arc<AtomicBool>,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            return;
        }
        let Ok(stream) = conn else { continue };
        let service = Arc::clone(&service);
        let batcher = batcher.clone();
        let _ = std::thread::Builder::new()
            .name("dalvq-serve-conn".into())
            .spawn(move || {
                let _ = serve_connection(stream, &service, batcher.as_deref());
            });
    }
}

/// One connection: frames in, frames out, until the peer hangs up.
///
/// Tracing wraps the whole per-frame lifetime: the trace origin is the
/// instant the frame arrived, the `decode` span is replayed from the
/// stage timer, the handler records its own children, and the `encode`
/// span is measured on the inner reply *before* the optional
/// [`Response::Traced`] envelope — whose span list must already be
/// final — goes out.
fn serve_connection(
    stream: TcpStream,
    service: &VqService,
    batcher: Option<&Batcher>,
) -> Result<()> {
    stream.set_nodelay(true).ok(); // request/response pattern
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    while let Some(payload) = read_frame(&mut reader)? {
        let t_start = Instant::now();
        let decoded = Request::decode(&payload);
        let decode_us = t_start.elapsed().as_micros() as u64;
        service.tel().decode_us.record(decode_us);
        // Unwrap the optional trace-context envelope; the inner request
        // is handled exactly as if it had arrived bare.
        let (decoded, wire_ctx) = match decoded {
            Ok(Request::Traced { hi, lo, parent, inner }) => {
                (Ok(*inner), Some((hi, lo, parent)))
            }
            other => (other, None),
        };
        let tracer = service.telemetry().tracer();
        let mut tb = match wire_ctx {
            // A remote caller already committed to this trace: join it
            // even when local sampling is off.
            Some((hi, lo, _)) => Some(tracer.begin_forced_at(hi, lo, t_start)),
            None => tracer.begin_at(t_start),
        };
        let wire_parent = wire_ctx.map_or(NO_PARENT, |(_, _, parent)| parent);
        let (resp, root) = match decoded {
            Ok(req) => {
                handle(service, batcher, req, decode_us, wire_parent, &mut tb)
            }
            Err(e) => {
                (Response::Error { message: format!("{e:#}") }, NO_PARENT)
            }
        };
        let t_encode = Instant::now();
        let inner_bytes = resp.encode();
        let encode_us = t_encode.elapsed().as_micros() as u64;
        service.tel().encode_us.record(encode_us);
        let frame = match tb.take() {
            None => inner_bytes,
            Some(mut tb) => {
                if root != NO_PARENT {
                    let enc_start =
                        t_encode.duration_since(t_start).as_micros() as u64;
                    tb.add("encode", root, enc_start, encode_us);
                    tb.end(root);
                }
                let frame = match wire_ctx {
                    Some((hi, lo, _)) => {
                        // Ship the root span detached (parent 0). Its
                        // true parent is a span id in the *caller's*
                        // ring; span ids are sequential in both
                        // processes, so shipping the raw foreign id
                        // could collide with one of our own ids and
                        // mis-nest the tree when the caller grafts.
                        let mut spans = wire_spans(tb.spans());
                        if let Some(r) =
                            spans.iter_mut().find(|s| s.id == root)
                        {
                            r.parent = NO_PARENT;
                        }
                        encode_traced_response(hi, lo, &spans, &inner_bytes)
                    }
                    None => inner_bytes,
                };
                tracer.commit(tb);
                frame
            }
        };
        write_frame(&mut writer, &frame)?;
    }
    Ok(())
}

/// [`SpanRec`]s in wire shape.
fn wire_spans(spans: &[SpanRec]) -> Vec<WireSpan> {
    spans
        .iter()
        .map(|s| WireSpan {
            id: s.id,
            parent: s.parent,
            start_us: s.start_us,
            dur_us: s.dur_us,
            name: s.name.clone(),
        })
        .collect()
}

/// A [`FinishedTrace`] in wire shape (for the `Trace` op's reply).
fn wire_trace(t: FinishedTrace) -> WireTrace {
    WireTrace { hi: t.hi, lo: t.lo, ts_ms: t.ts_ms, spans: wire_spans(&t.spans) }
}

/// Dispatch one request with per-op accounting wrapped around
/// [`dispatch`]: count the request into its op family, time the whole
/// handler into the op's latency histogram, and — when the slow-query
/// log is armed — journal any request over the threshold with whatever
/// stage breakdown the dispatch recorded.
///
/// When a trace is live, opens the root `req.<op>` span (under the wire
/// context's parent, if any), replays the already-measured `decode`
/// stage as its first child, and returns the root's id so the caller
/// can hang the `encode` span off it and close it after framing.
fn handle(
    service: &VqService,
    batcher: Option<&Batcher>,
    req: Request,
    decode_us: u64,
    wire_parent: u64,
    tb: &mut Option<TraceBuilder>,
) -> (Response, u64) {
    let tel = service.tel();
    let (op_name, op) = match &req {
        Request::Encode { .. } => ("encode", &tel.op_encode),
        Request::Nearest { .. } => ("nearest", &tel.op_nearest),
        Request::Distortion { .. } => ("distortion", &tel.op_distortion),
        Request::Ingest { .. } => ("ingest", &tel.op_ingest),
        Request::Stats => ("stats", &tel.op_other),
        Request::Checkpoint => ("checkpoint", &tel.op_other),
        Request::Rebalance { .. } => ("rebalance", &tel.op_other),
        Request::FetchState { .. } => ("fetch_state", &tel.op_other),
        Request::Metrics { .. } => ("metrics", &tel.op_other),
        Request::Trace { .. } => ("trace", &tel.op_other),
        Request::Traced { .. } => ("traced", &tel.op_other),
    };
    op.requests.inc();
    let mut root = NO_PARENT;
    if let Some(tb) = tb.as_mut() {
        root = tb.begin(&format!("req.{op_name}"), wire_parent);
        tb.add("decode", root, 0, decode_us);
    }
    let t0 = Instant::now();
    let mut stages: Option<(u64, u64)> = None;
    let resp = dispatch(service, batcher, req, &mut stages, root, tb);
    let total_us = t0.elapsed().as_micros() as u64;
    op.total_us.record(total_us);
    let threshold = service.slow_query_us();
    if threshold > 0 && total_us > threshold {
        tel.slow_queries.inc();
        let breakdown = match stages {
            Some((route_us, scan_us)) => {
                format!(", route {route_us} us + scan {scan_us} us")
            }
            None => String::new(),
        };
        service.telemetry().journal().warn(
            "slow_query",
            format!(
                "{op_name} took {total_us} us (threshold {threshold} us, \
                 {} shards{breakdown})",
                service.shards()
            ),
        );
    }
    (resp, root)
}

/// Dispatch one request through the service's routed query/ingest surface
/// (multi-probe over the shard fleets happens inside [`VqService`]).
/// Read queries run the timed path and report their (route, scan) µs
/// through `stages` for the slow-query log.
///
/// On a follower, every leader-only op — writes (`Ingest`,
/// `Checkpoint`, `Rebalance`) and state shipping (`FetchState`) —
/// answers `NotLeader` with the leader's address, so a client can
/// redirect instead of parsing an error string. The read surface —
/// `Metrics` included (a follower's telemetry is its own, not the
/// leader's) — is identical on both roles.
fn dispatch(
    service: &VqService,
    batcher: Option<&Batcher>,
    req: Request,
    stages: &mut Option<(u64, u64)>,
    root: u64,
    tb: &mut Option<TraceBuilder>,
) -> Response {
    if matches!(
        req,
        Request::Ingest { .. }
            | Request::Checkpoint
            | Request::Rebalance { .. }
            | Request::FetchState { .. }
    ) {
        if let Some(leader) = service.follower_of() {
            return Response::NotLeader { leader };
        }
    }
    let dim = service.dim();
    let check = |points: &[f32]| -> Option<Response> {
        if points.is_empty() || points.len() % dim != 0 {
            Some(Response::Error {
                message: format!(
                    "batch of {} floats is not a positive multiple of dim {dim}",
                    points.len()
                ),
            })
        } else {
            None
        }
    };
    // Admission: a request small enough to *arrive* can still demand a
    // reply too large to *frame* (at dim 1 a Nearest request of n points
    // is 5 + 4n bytes but its reply is 17 + 8n — past the cap for the
    // top half of the admissible range). Reject those here, before any
    // routing or scan work is spent on an unanswerable query.
    let reply_cap = |op: &str, fixed: usize, per_point: usize, n: usize| {
        let bytes = fixed + per_point * n;
        if bytes > MAX_FRAME as usize {
            Some(Response::Error {
                message: format!(
                    "{op} reply for {n} points would be {bytes} bytes, over \
                     the {MAX_FRAME}-byte frame cap; split the batch",
                ),
            })
        } else {
            None
        }
    };
    let count_query = || {
        service
            .counters()
            .queries
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    };
    match req {
        Request::Encode { points } => {
            if let Some(err) = check(&points) {
                return err;
            }
            // Codes reply: op + version + len prefix + 4 bytes/code.
            if let Some(err) = reply_cap("encode", 13, 4, points.len() / dim) {
                return err;
            }
            count_query();
            let q = run_query(service, batcher, &points, root, tb);
            *stages = Some((q.route_us, q.scan_us));
            Response::Codes { version: q.version, codes: q.codes }
        }
        Request::Nearest { points } => {
            if let Some(err) = check(&points) {
                return err;
            }
            // Neighbors reply: op + version + two prefixed f32/u32 runs.
            if let Some(err) = reply_cap("nearest", 17, 8, points.len() / dim) {
                return err;
            }
            count_query();
            let q = run_query(service, batcher, &points, root, tb);
            *stages = Some((q.route_us, q.scan_us));
            Response::Neighbors {
                version: q.version,
                indices: q.codes,
                dists: q.dists,
            }
        }
        Request::Distortion { points } => {
            if let Some(err) = check(&points) {
                return err;
            }
            count_query();
            let q = run_query(service, batcher, &points, root, tb);
            *stages = Some((q.route_us, q.scan_us));
            // check() rejected empty batches, so dists is never empty.
            let sum: f64 = q.dists.iter().map(|d| *d as f64).sum();
            Response::Distortion {
                version: q.version,
                value: sum / q.dists.len() as f64,
            }
        }
        Request::Ingest { points } => match service.ingest(&points) {
            Ok((accepted, shed)) => Response::IngestAck { accepted, shed },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        Request::Stats => {
            let s = service.stats();
            Response::Stats(StatsReply {
                version: s.version,
                kappa: s.kappa as u64,
                dim: s.dim as u64,
                workers: s.workers as u64,
                shards: s.shards as u64,
                probe_n: s.probe_n as u64,
                router_version: s.router_version,
                rebalances: s.rebalances,
                merges: s.merges,
                ingested: s.ingested,
                ingest_shed: s.ingest_shed,
                queries: s.queries,
                shard_versions: s.shard_versions,
                shard_merges: s.shard_merges,
                shard_ingest: s.shard_ingest,
                shard_shed: s.shard_shed,
                last_checkpoint: s.last_checkpoint,
                state_dir: s.state_dir.unwrap_or_default(),
                role: s.role,
                leader_addr: s.leader_addr.unwrap_or_default(),
                sync_lag_folds: s.sync_lag_folds,
                last_sync: s.last_sync_ms,
                uptime_ms: s.uptime_ms,
                op_encode: s.op_encode,
                op_nearest: s.op_nearest,
                op_distortion: s.op_distortion,
                op_ingest: s.op_ingest,
            })
        }
        Request::Metrics { max_events } => Response::Metrics(metrics_reply(
            service.metrics_snapshot(max_events as usize),
        )),
        Request::Checkpoint => match service.checkpoint_now() {
            Ok(versions) => Response::CheckpointAck { versions },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        // The epoch swap happens entirely inside the service; this
        // connection blocks until the new partition serves, while reads
        // on other connections keep answering from the old epoch.
        Request::Rebalance { want_remap } => match service.rebalance() {
            Ok(out) => Response::RebalanceAck {
                router_version: out.router_version,
                moved_rows: out.moved_rows,
                shard_versions: out.shard_versions,
                remap: if want_remap { out.remap } else { Vec::new() },
            },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        // Replication: ship the durable state as one consistent bundle.
        // The service records `state.cut` / `state.ship` children when a
        // trace is live (a follower's wire context joins them into its
        // own sync-cycle trace).
        Request::FetchState { have_generation } => {
            match service.fetch_state(have_generation, tb.as_mut(), root) {
                Ok(shipment) => Response::State(shipment),
                Err(e) => Response::Error { message: format!("{e:#}") },
            }
        }
        Request::Trace { max_traces } => Response::Traces(
            service
                .telemetry()
                .tracer()
                .recent(max_traces as usize)
                .into_iter()
                .map(wire_trace)
                .collect(),
        ),
        // The connection loop unwraps the envelope before dispatch, and
        // the decoder rejects nesting — this arm is unreachable short of
        // a bug, and answers cleanly rather than panicking.
        Request::Traced { .. } => Response::Error {
            message: "nested trace envelopes are not allowed".into(),
        },
    }
}

/// One read batch through the query plane: the coalescer when armed
/// (falling back to the direct path if it is already shut down), else an
/// immediate fused scan on this connection thread. Either route answers
/// bit-identically; only latency and staleness differ.
///
/// A live trace gets the stage breakdown as child spans of `root`:
/// `route` + `scan` on both paths (the measurements come from the fused
/// scan either way), plus `batch.wait` / `batch.scatter` around them
/// when the coalescer carried the request — the queueing delay and the
/// fan-back are exactly the latency the batching trade-off adds.
fn run_query(
    service: &VqService,
    batcher: Option<&Batcher>,
    points: &[f32],
    root: u64,
    tb: &mut Option<TraceBuilder>,
) -> TimedQuery {
    let s0 = tb.as_ref().map_or(0, |t| t.now_us());
    if let Some(b) = batcher {
        if let Some(a) = b.submit(points.to_vec()) {
            if let Some(tb) = tb.as_mut() {
                tb.add("batch.wait", root, s0, a.wait_us);
                let r0 = s0 + a.wait_us;
                tb.add("route", root, r0, a.route_us);
                tb.add("scan", root, r0 + a.route_us, a.scan_us);
                tb.add(
                    "batch.scatter",
                    root,
                    r0 + a.route_us + a.scan_us,
                    a.scatter_us,
                );
            }
            return TimedQuery {
                version: a.version,
                codes: a.codes,
                dists: a.dists,
                route_us: a.route_us,
                scan_us: a.scan_us,
            };
        }
    }
    let q = service.query_nearest_timed(points, service.probe_n());
    if let Some(tb) = tb.as_mut() {
        tb.add("route", root, s0, q.route_us);
        tb.add("scan", root, s0 + q.route_us, q.scan_us);
    }
    q
}

/// A telemetry snapshot in wire shape. By value: the snapshot is already
/// this handler's own copy, so the strings and vectors move instead of
/// cloning.
fn metrics_reply(snap: TelemetrySnapshot) -> MetricsReply {
    MetricsReply {
        uptime_ms: snap.uptime_ms,
        counters: snap.counters,
        gauges: snap.gauges,
        hists: snap
            .hists
            .into_iter()
            .map(|(name, s)| MetricHist {
                name,
                count: s.count,
                mean_us: s.mean_us,
                p50_us: s.p50_us,
                p95_us: s.p95_us,
                p99_us: s.p99_us,
                max_us: s.max_us,
            })
            .collect(),
        events: snap
            .events
            .into_iter()
            .map(|e| MetricEvent {
                seq: e.seq,
                ts_ms: e.ts_ms,
                level: e.level.as_u8(),
                kind: e.kind,
                message: e.message,
            })
            .collect(),
    }
}
