//! `dalvq trace`: fetch and render the server's sampled distributed
//! traces.
//!
//! Polls the `Trace` wire op once and prints each returned trace as an
//! indented span tree (offset + duration per span, microseconds) followed
//! by its critical path — the root-to-leaf chain that dominated the
//! request's wall time. Rendering is a pure function of the wire reply
//! ([`render`]), so the unit tests exercise it on synthetic traces
//! without a server.
//!
//! Span parents may dangle: a trace joined over the wire (a follower's
//! `sync.cycle` stamping its id on `FetchState`) leaves the remote
//! server's root parented under a span id that lives in the *caller's*
//! ring, not its own. Every span whose parent is not present in the same
//! trace therefore renders as a root — never dropped, never trusted to
//! recurse (a lying peer cannot hang the renderer with a parent cycle).

use anyhow::Result;

use super::client::Client;
use super::protocol::{WireSpan, WireTrace};

/// One `dalvq trace` invocation.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Server address (`host:port`).
    pub addr: String,
    /// Newest-first traces to fetch and print.
    pub max_traces: u32,
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self { addr: "127.0.0.1:7171".into(), max_traces: 4 }
    }
}

/// Fetch the newest `spec.max_traces` traces from `spec.addr` and print
/// them, newest first.
pub fn run_trace(spec: &TraceSpec) -> Result<()> {
    let mut client = Client::connect(spec.addr.as_str())?;
    let traces = client.trace(spec.max_traces)?;
    print!("{}", render(&spec.addr, &traces));
    Ok(())
}

/// Render a `Trace` reply. Pure: everything shown is a function of the
/// arguments.
pub fn render(addr: &str, traces: &[WireTrace]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "dalvq trace — {addr}: {} sampled trace(s), newest first\n",
        traces.len()
    ));
    if traces.is_empty() {
        s.push_str(
            "  (none — arm sampling with --trace-sample, or wait for a \
             slow-query keep)\n",
        );
    }
    for t in traces {
        let total: u64 = t
            .spans
            .iter()
            .map(|sp| sp.start_us + sp.dur_us)
            .max()
            .unwrap_or(0);
        s.push('\n');
        s.push_str(&format!(
            "trace {:016x}{:016x}  +{} ms  {} span(s)  {} us\n",
            t.hi,
            t.lo,
            t.ts_ms,
            t.spans.len(),
            total,
        ));
        for line in render_tree(&t.spans).lines() {
            s.push_str(&format!("  {line}\n"));
        }
        let path = critical_path(&t.spans);
        if path.len() > 1 {
            let names: Vec<&str> =
                path.iter().map(|sp| sp.name.as_str()).collect();
            let leaf = path.last().expect("non-empty path");
            s.push_str(&format!(
                "  critical path: {} ({} us of {} us)\n",
                names.join(" > "),
                leaf.dur_us,
                total,
            ));
        }
    }
    s
}

/// Indices of the spans that act as tree roots: parent 0 or a parent id
/// not present in the trace (a wire-joined trace's dangling parent).
fn root_indices(spans: &[WireSpan]) -> Vec<usize> {
    (0..spans.len())
        .filter(|&i| {
            let p = spans[i].parent;
            p == 0 || !spans.iter().any(|sp| sp.id == p)
        })
        .collect()
}

/// Direct children of `spans[i]`, in span order.
fn child_indices(spans: &[WireSpan], i: usize) -> Vec<usize> {
    let id = spans[i].id;
    (0..spans.len())
        .filter(|&c| c != i && spans[c].parent == id)
        .collect()
}

/// The span tree as indented text, one span per line:
/// `name  @offset_us +dur_us`. Spans with unresolvable parents render
/// as extra roots; a span is printed at most once, so even an
/// adversarial parent cycle terminates.
pub fn render_tree(spans: &[WireSpan]) -> String {
    let mut s = String::new();
    let mut seen = vec![false; spans.len()];
    // name column width across the whole trace (indent included)
    let width = spans
        .iter()
        .map(|sp| sp.name.len())
        .max()
        .unwrap_or(0)
        .max(12)
        + 6;
    for root in root_indices(spans) {
        // explicit stack: (index, depth)
        let mut stack = vec![(root, 0usize)];
        while let Some((i, depth)) = stack.pop() {
            if seen[i] {
                continue;
            }
            seen[i] = true;
            let sp = &spans[i];
            let label = format!("{}{}", "  ".repeat(depth), sp.name);
            s.push_str(&format!(
                "{label:<width$} @{:>7} us  +{:>7} us\n",
                sp.start_us, sp.dur_us,
            ));
            // push children reversed so they pop in span order
            for c in child_indices(spans, i).into_iter().rev() {
                stack.push((c, depth + 1));
            }
        }
    }
    // anything unreachable (self-parenting cycles) still gets a line
    for i in 0..spans.len() {
        if !seen[i] {
            let sp = &spans[i];
            s.push_str(&format!(
                "{:<width$} @{:>7} us  +{:>7} us\n",
                sp.name, sp.start_us, sp.dur_us,
            ));
        }
    }
    s
}

/// The chain of spans that dominated the trace: from the slowest root,
/// repeatedly descend into the slowest child. Each step is the span a
/// latency investigation should open next.
pub fn critical_path(spans: &[WireSpan]) -> Vec<&WireSpan> {
    let mut path = Vec::new();
    let Some(mut at) = root_indices(spans)
        .into_iter()
        .max_by_key(|&i| spans[i].dur_us)
    else {
        return path;
    };
    let mut hops = 0;
    loop {
        path.push(&spans[at]);
        hops += 1;
        if hops > spans.len() {
            break; // adversarial cycle; never loop forever
        }
        match child_indices(spans, at)
            .into_iter()
            .max_by_key(|&c| spans[c].dur_us)
        {
            Some(next) => at = next,
            None => break,
        }
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, parent: u64, start: u64, dur: u64, name: &str) -> WireSpan {
        WireSpan { id, parent, start_us: start, dur_us: dur, name: name.into() }
    }

    fn sample_trace() -> WireTrace {
        WireTrace {
            hi: 0xDEAD,
            lo: 0xBEEF,
            ts_ms: 1234,
            spans: vec![
                span(1, 0, 0, 5_000, "req.nearest"),
                span(2, 1, 0, 15, "decode"),
                span(3, 1, 20, 4_800, "scan"),
                span(4, 1, 4_850, 30, "encode"),
            ],
        }
    }

    #[test]
    fn render_shows_ids_trees_and_the_critical_path() {
        let screen = render("127.0.0.1:7171", &[sample_trace()]);
        assert!(
            screen.contains("000000000000dead000000000000beef"),
            "{screen}"
        );
        assert!(screen.contains("req.nearest"), "{screen}");
        // children are indented under the root
        assert!(screen.contains("  scan"), "{screen}");
        // the scan dominates: it IS the critical path's leaf
        assert!(
            screen.contains("critical path: req.nearest > scan"),
            "{screen}"
        );
        assert!(screen.contains("4800 us of 5000 us"), "{screen}");
    }

    #[test]
    fn render_empty_ring_explains_how_to_arm() {
        let screen = render("x:1", &[]);
        assert!(screen.contains("--trace-sample"), "{screen}");
    }

    #[test]
    fn dangling_parents_render_as_roots_not_drops() {
        // A wire-joined trace: the remote root's parent (99) lives in the
        // caller's ring, not this trace. It must still print, un-indented.
        let spans =
            vec![span(1, 99, 0, 100, "req.fetch_state"), span(2, 1, 5, 80, "state.cut")];
        let tree = render_tree(&spans);
        assert!(tree.lines().next().unwrap().starts_with("req.fetch_state"));
        assert!(tree.contains("  state.cut"));
        let path = critical_path(&spans);
        assert_eq!(path.len(), 2);
        assert_eq!(path[1].name, "state.cut");
    }

    #[test]
    fn adversarial_parent_cycles_terminate() {
        // Two spans parenting each other: no root at all. Every span
        // still renders exactly once, and the critical path terminates.
        let spans = vec![span(1, 2, 0, 10, "a"), span(2, 1, 0, 10, "b")];
        let tree = render_tree(&spans);
        assert_eq!(tree.lines().count(), 2, "{tree}");
        assert!(critical_path(&spans).len() <= 3);
    }

    #[test]
    fn critical_path_follows_the_slowest_child_at_every_hop() {
        let spans = vec![
            span(1, 0, 0, 1_000, "root"),
            span(2, 1, 0, 100, "fast"),
            span(3, 1, 100, 800, "slow"),
            span(4, 3, 100, 700, "slowest-leaf"),
        ];
        let names: Vec<&str> =
            critical_path(&spans).iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["root", "slow", "slowest-leaf"]);
    }
}
