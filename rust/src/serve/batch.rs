//! Cross-request micro-batch coalescing — the opt-in queue between the
//! TCP front-end and the fused shard scan.
//!
//! With `--batch-window-us` armed, read requests (encode / nearest /
//! distortion) no longer scan on their own connection threads: each one
//! enqueues its points into the [`Batcher`] and blocks until a drain
//! answers it. A single drain thread opens a batch on the first queued
//! request, keeps collecting until either `batch_window_us` elapses or
//! the batch holds `batch_max_points` points, then runs ONE fused
//! multi-probe scan over the concatenation and hands each request back
//! its slice of the answers. The shard-grouped kernel thus sweeps every
//! probed codebook once per *drain* instead of once per *request* —
//! Annaji & Rao's shared-memory LBG batching argument applied across
//! connections.
//!
//! Semantics: answers are bit-identical to the direct path — the drain
//! calls the same [`VqService::query_nearest_timed`], and per point the
//! fused scan is bit-identical to the scalar one. What coalescing *does*
//! change is staleness: a request may be answered up to one window later
//! than an immediate scan would, against whatever snapshot epoch is
//! current at drain time. That window is exactly the bounded-delay
//! staleness Patra's convergence analysis already covers for the
//! training path, so a coalesced reader is no worse off than any
//! delayed-view consumer.
//!
//! Lifecycle: [`Batcher::start`] spawns the drain thread;
//! [`Batcher::shutdown`] closes the queue (in-flight requests are still
//! answered) and joins it. After shutdown [`Batcher::submit`] returns
//! `None` and the front-end falls back to the direct scan, so a request
//! racing a shutdown is answered either way.

use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::service::VqService;

/// One queued read request: its points and the one-shot channel its
/// slice of the coalesced answer returns on.
struct Pending {
    points: Vec<f32>,
    enqueued: Instant,
    reply: mpsc::Sender<BatchAnswer>,
}

/// A request's slice of one coalesced scan — the same shape
/// [`VqService::query_nearest_timed`] answers with, restricted to this
/// request's points. `route_us`/`scan_us` are the drained batch's shared
/// stage timings (one scan answered every member).
pub(crate) struct BatchAnswer {
    pub version: u64,
    pub codes: Vec<u32>,
    pub dists: Vec<f32>,
    pub route_us: u64,
    pub scan_us: u64,
    /// This member's queueing delay: enqueue to the fused scan starting.
    /// Per-request (an opener waits the whole window; a last-moment
    /// arrival waits almost nothing) — the `batch.wait` trace span.
    pub wait_us: u64,
    /// This member's fan-back delay: fused scan done to this slice being
    /// sent — the `batch.scatter` trace span.
    pub scatter_us: u64,
}

/// The coalescer. One per server, created only when
/// `ServeConfig::batch_window_us > 0`; the default-off path never
/// constructs it and is byte-for-byte today's behavior.
pub(crate) struct Batcher {
    /// `None` after shutdown; dropping the last sender ends the drain.
    tx: Mutex<Option<mpsc::Sender<Pending>>>,
    drain: Mutex<Option<JoinHandle<()>>>,
}

impl Batcher {
    /// Spawn the drain thread against `service`, reading the window and
    /// point budget from its serve config.
    pub fn start(service: Arc<VqService>) -> Arc<Batcher> {
        let window = Duration::from_micros(service.batch_window_us());
        let max_points = service.batch_max_points().max(1);
        let (tx, rx) = mpsc::channel();
        let drain = std::thread::Builder::new()
            .name("dalvq-serve-batch".into())
            .spawn(move || drain_loop(rx, service, window, max_points))
            .expect("spawning batch drain thread");
        Arc::new(Batcher {
            tx: Mutex::new(Some(tx)),
            drain: Mutex::new(Some(drain)),
        })
    }

    /// Queue one read batch (`points` already shape-checked by the
    /// caller) and block until the drain that answers it. `None` once
    /// the batcher is shut down — the caller falls back to the direct
    /// scan path.
    pub fn submit(&self, points: Vec<f32>) -> Option<BatchAnswer> {
        let tx = self.tx.lock().unwrap_or_else(|e| e.into_inner()).clone()?;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Pending {
            points,
            enqueued: Instant::now(),
            reply: reply_tx,
        })
        .ok()?;
        reply_rx.recv().ok()
    }

    /// Close the queue and join the drain thread. Requests already in
    /// the queue are drained and answered first; later submits get
    /// `None`. Idempotent.
    pub fn shutdown(&self) {
        let tx = self.tx.lock().unwrap_or_else(|e| e.into_inner()).take();
        drop(tx);
        let drain =
            self.drain.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(j) = drain {
            let _ = j.join();
        }
    }
}

/// The drain loop: block for a batch opener, collect until the window
/// closes or the point budget fills, scan once, scatter the slices back.
fn drain_loop(
    rx: mpsc::Receiver<Pending>,
    service: Arc<VqService>,
    window: Duration,
    max_points: usize,
) {
    let dim = service.dim();
    loop {
        // A closed, empty queue is the shutdown signal.
        let first = match rx.recv() {
            Ok(p) => p,
            Err(_) => return,
        };
        let deadline = Instant::now() + window;
        let mut total_points = first.points.len() / dim;
        let mut batch = vec![first];
        let mut closed = false;
        while total_points < max_points {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => {
                    total_points += p.points.len() / dim;
                    batch.push(p);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Shutdown mid-collection: answer what we hold.
                    closed = true;
                    break;
                }
            }
        }

        // One fused multi-probe scan over the concatenation; every
        // member's answer is its slice, computed against the same
        // snapshot set (members can never straddle an epoch swap).
        let mut all = Vec::with_capacity(total_points * dim);
        for p in &batch {
            all.extend_from_slice(&p.points);
        }
        let t_scan = Instant::now();
        let q = service.query_nearest_timed(&all, service.probe_n());

        let tel = service.tel();
        tel.batch_size.record(total_points as u64);
        let drained = Instant::now();
        for p in &batch {
            tel.batch_wait_us
                .record(drained.duration_since(p.enqueued).as_micros() as u64);
        }

        let mut off = 0usize;
        for p in batch {
            let n = p.points.len() / dim;
            let ans = BatchAnswer {
                version: q.version,
                codes: q.codes[off..off + n].to_vec(),
                dists: q.dists[off..off + n].to_vec(),
                route_us: q.route_us,
                scan_us: q.scan_us,
                wait_us: t_scan.duration_since(p.enqueued).as_micros() as u64,
                scatter_us: drained.elapsed().as_micros() as u64,
            };
            off += n;
            // A peer that hung up mid-wait just drops its slice.
            let _ = p.reply.send(ans);
        }
        if closed {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, SchemeConfig, ServeConfig};
    use crate::sim::DelayModel;
    use crate::vq::Schedule;

    fn tiny_cfg() -> (ExperimentConfig, ServeConfig) {
        let mut cfg = ExperimentConfig::default();
        cfg.m = 1;
        cfg.data.mixture.components = 4;
        cfg.data.mixture.dim = 2;
        cfg.data.n_total = 2_000;
        cfg.data.eval_points = 256;
        cfg.vq.kappa = 8;
        cfg.vq.schedule = Schedule::Constant { eps0: 0.01 };
        cfg.scheme = SchemeConfig::AsyncDelta {
            tau: 10,
            up_delay: DelayModel::Instant,
            down_delay: DelayModel::Instant,
        };
        let mut serve = ServeConfig::default();
        serve.points_per_exchange = 50;
        serve.point_compute = 2e-6;
        serve.shards = 4;
        serve.probe_n = 2;
        serve.batch_window_us = 300;
        serve.batch_max_points = 64;
        (cfg, serve)
    }

    #[test]
    fn concurrent_submits_get_their_own_bit_identical_slices() {
        let (cfg, serve) = tiny_cfg();
        let svc = VqService::start(&cfg, &serve).unwrap();
        // Quiesce so the direct-path oracle reads the same frozen
        // snapshots every drain will (read path survives shutdown).
        svc.shutdown().unwrap();
        let batcher = Batcher::start(Arc::clone(&svc));
        let eval = cfg.data.mixture.eval_sample(96, cfg.seed);
        let mut joins = Vec::new();
        for t in 0..6usize {
            let batcher = Arc::clone(&batcher);
            let svc = Arc::clone(&svc);
            // Each thread asks about a different sub-batch, repeatedly,
            // so drains interleave requests of different sizes.
            let mine: Vec<f32> =
                eval[t * 16 * 2..(t + 1) * 16 * 2].to_vec();
            joins.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    let ans = batcher.submit(mine.clone()).expect("live batcher");
                    let (version, codes, dists) =
                        svc.query_nearest_probed(&mine, svc.probe_n());
                    assert_eq!(ans.version, version);
                    assert_eq!(ans.codes, codes);
                    assert_eq!(
                        ans.dists.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
                        dists.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
                    );
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // the drains recorded themselves
        let snap = svc.metrics_snapshot(0);
        let hist = |name: &str| {
            snap.hists
                .iter()
                .find(|(n, _)| n == name)
                .unwrap_or_else(|| panic!("no histogram {name}"))
                .1
                .clone()
        };
        assert!(hist("batch.size").count > 0);
        assert!(hist("batch.wait_us").count > 0);
        batcher.shutdown();
        // post-shutdown submits tell the caller to go direct
        assert!(batcher.submit(vec![0.0, 0.0]).is_none());
    }

    #[test]
    fn shutdown_with_empty_queue_is_clean_and_idempotent() {
        let (cfg, mut serve) = tiny_cfg();
        serve.batch_window_us = 50;
        let svc = VqService::start(&cfg, &serve).unwrap();
        svc.shutdown().unwrap();
        let batcher = Batcher::start(Arc::clone(&svc));
        batcher.shutdown();
        batcher.shutdown();
        assert!(batcher.submit(vec![1.0, 2.0]).is_none());
    }
}
