//! Online VQ serving: training and inference coexisting in one process.
//!
//! The paper's endpoint is a codebook maintained *online* by barrier-free
//! delta exchange (eq. 9 — the CloudDALVQ deployment), and its companion
//! analysis (Patra: convergence of distributed asynchronous LVQ) is about
//! keeping that shared version usable while it is being updated. This
//! subsystem is that story as a service:
//!
//! * **Sharded codebook** — the prototype space is partitioned across `S`
//!   independent fleets by a coarse-quantizer [`Router`] (trained by a
//!   short k-means pass, then frozen *within its epoch*). Shards never
//!   synchronize — Patra's asynchronous-LVQ analysis applies per shard —
//!   and per-query distance work drops to `probe_n * kappa/S * dim`.
//! * **Live rebalancing** — the partition is a **versioned router
//!   epoch**, `Arc`-swapped like a snapshot: when per-shard ingest
//!   counters diverge (drift piling the stream onto one shard), the
//!   service quiesces its fleets, re-partitions the *checkpointed* state
//!   offline ([`crate::persist::rebalance`]: ingest-weighted router
//!   retrain + prototype-row migration) and restarts fresh fleets at the
//!   bumped router version — queries answer from the old epoch until the
//!   new one publishes. A skew monitor auto-triggers this
//!   (`rebalance_skew`); the `Rebalance` wire op and `dalvq state
//!   rebalance` trigger it by hand.
//! * **Write path** — each shard's worker fleet ([`run_serve_worker`])
//!   keeps learning via the async-delta protocol on the [`crate::cloud`]
//!   substrate (queue + blob + dedicated reducer), fed by client
//!   ingestion routed to the owning shard; each worker's local corpus is
//!   a sliding window, so a drifting input distribution is tracked, not
//!   averaged away.
//! * **Publication** — each shard's reducer epoch-swaps immutable
//!   [`Snapshot`]s into its [`SnapshotStore`]; readers clone an `Arc`,
//!   never blocking the fold loop.
//! * **Read path** — **encode** (quantize to global prototype codes),
//!   **nearest** (centroid lookup with distances) and **distortion**
//!   (batch criterion, paper eq. 2), multi-probing the `probe_n` nearest
//!   shards so answers stay correct near shard boundaries.
//! * **Batched query plane** — the scan stage is shard-grouped and
//!   fused ([`crate::vq::nearest_batch`]): each request's (point, probe)
//!   pairs gather per shard and every probed codebook is swept once per
//!   batch instead of once per point, bit-identically to the scalar
//!   path; `--batch-window-us` additionally coalesces concurrent read
//!   requests into one fused scan per drain tick (opt-in, default off —
//!   see `docs/ARCHITECTURE.md` §Batched query plane).
//! * **Front-end** — a `std::net` TCP [`Server`] speaking a
//!   length-prefixed binary [`protocol`]: a non-blocking event loop
//!   (readiness polling, request pipelining, vectored writes, zero-copy
//!   frame decode) feeding a fixed worker pool sized to cores, with
//!   per-connection admission control (rate and in-flight quotas, a
//!   brownout watermark that sheds ingest before reads) answering
//!   refusals in-band with `Throttled` + retry-after; an in-crate
//!   [`Client`], and a load generator ([`run_load`]) that measures
//!   throughput and latency percentiles into [`crate::metrics`] types
//!   and can pipeline requests (`--pipeline`).
//! * **Durability** — with a `state_dir`, a background checkpointer
//!   ([`crate::persist`]) snapshots each shard's published epoch to disk
//!   every `checkpoint_every` folds (atomic temp+fsync+rename; the read
//!   and fold paths never block on the disk), and a restarted service
//!   warm-starts from the saved state: router restored verbatim, fleets
//!   seeded from the checkpointed codebooks at their saved versions
//!   instead of retraining. The wire protocol's `Checkpoint` op forces a
//!   flush.
//! * **Telemetry** — every request is measured where it is served: the
//!   [`crate::obs`] plane keeps per-op latency histograms with stage
//!   timings (frame decode → route → shard scan → encode), per-shard
//!   queue-depth/shed gauges and a bounded journal of fleet events
//!   (checkpoint flushes, sync adoptions, rebalance phases, slow
//!   queries), exposed three ways: the `Metrics` wire op, the live
//!   `dalvq top` screen ([`run_top`]), and `--metrics-file` periodic
//!   JSON snapshots. `docs/OBSERVABILITY.md` is the metric catalog.
//! * **Distributed tracing** — `--trace-sample N` arms a deterministic
//!   1-in-N request sampler ([`crate::obs::Tracer`]); a sampled request
//!   records a span tree through every stage it crosses (handler stages,
//!   the batch coalescer, training exchange intervals, reducer folds,
//!   and whole replication sync cycles — the follower stamps its trace
//!   id on `FetchState`, so the leader's cut/ship spans land inside the
//!   follower's trace: ONE trace across two processes). Slow requests
//!   are always kept. Exposed via the `Trace` wire op, `dalvq trace`
//!   ([`run_trace`]), `dalvq loadtest --trace`, and `--metrics-file`
//!   snapshots. `docs/OBSERVABILITY.md` §Distributed tracing is the
//!   span catalog.
//! * **Replication** — a service started with `follow: Some(leader)` is
//!   a **read-only follower**: it warm-starts from the leader's shipped
//!   checkpoint bundle (the `FetchState` wire op +
//!   [`crate::persist::ship`]), serves the full read surface from
//!   epoch-swapped adopted snapshots, answers writes with `NotLeader`,
//!   and keeps polling for new checkpoint generations — query capacity
//!   scales out across processes with zero coordination on the write
//!   path, the paper's asynchronous delayed-exchange argument applied to
//!   serving. Replication v2 makes this a production sync *tier*:
//!   steady-state polls ship **deltas** (only the shard files whose
//!   version advanced, chunked under the frame cap), a follower with a
//!   mirror dir answers `FetchState` itself so sync load forms a
//!   **fan-out tree** instead of a star, clients follow `NotLeader`
//!   redirects automatically, and `--miss-threshold` arms **automatic
//!   failover**: a follower that loses leader contact promotes from its
//!   byte-identical mirror at a bumped generation, and a returning old
//!   leader demotes on seeing it (the `Demote` wire op). The
//!   deterministic fault-injection layer ([`faults`]) drives the
//!   `replication_v2_e2e` proof suite.
//!
//! `dalvq serve` / `dalvq serve --follow` / `dalvq loadtest` / `dalvq
//! top` / `dalvq state inspect` / `dalvq state rebalance` are the CLI
//! entry points;
//! the `serve_e2e`, `persist_e2e`, `rebalance_e2e` and `replication_e2e`
//! integration tests run the whole stack in-process. `docs/PROTOCOL.md`
//! is the byte-level wire reference; `docs/ARCHITECTURE.md` the system
//! overview.

mod batch;
mod client;
mod eventloop;
/// Deterministic, seeded fault injection on the replication path
/// (test-facing; disarmed in production).
pub mod faults;
mod loadgen;
/// The length-prefixed binary wire protocol (see `docs/PROTOCOL.md`).
pub mod protocol;
mod router;
mod server;
mod service;
mod snapshot;
mod top;
mod traceview;
mod worker;

pub use client::Client;
pub use loadgen::{
    component_shares, max_over_mean, run_load, LoadReport, LoadSpec, OpCounts,
    TraceSample, TRACE_EVERY,
};
pub use router::Router;
pub use server::Server;
pub use service::{
    RebalanceOutcome, ServeCounters, ServeOutcome, ServeStats, ShardOutcome,
    VqService,
};
pub use snapshot::{Snapshot, SnapshotStore};
pub use top::{run_top, TopSpec};
pub use traceview::{run_trace, TraceSpec};
pub use worker::{run_serve_worker, ServeWorkerOutcome, ServeWorkerParams};
