//! Deterministic fault injection for the replication path.
//!
//! The replication v2 acceptance suite has to *prove* failover: kill a
//! leader mid-ship, partition a mid-tree relay, and show every survivor
//! converges. Doing that with real signals and raw sockets is flaky;
//! doing it with named fault points is deterministic. The sync and
//! shipping code visits [`hit`] / [`hit_bytes`] at well-known points
//! (below), and a test arms a [`FaultPlan`] — a seeded, scriptable list
//! of rules saying *which* visits at *which* points drop, stall, or
//! truncate. Disarmed (the production state), a hit is one relaxed
//! atomic load.
//!
//! ## Points
//!
//! | point            | where                                             |
//! |------------------|---------------------------------------------------|
//! | `sync.fetch`     | follower, before each `FetchState` poll           |
//! | `sync.chunk`     | follower, before each `FetchChunk` fetch          |
//! | `sync.files`     | follower, shipped bytes in hand (byte-carrying)   |
//! | `sync.decode`    | follower, before validating the assembled bundle  |
//! | `sync.mirror`    | follower, before mirroring the bundle to disk     |
//! | `sync.adopt`     | follower, before swapping the serving epoch       |
//! | `state.cut`      | shipper, before cutting a bundle from its dir     |
//! | `state.ship`     | shipper, cut in hand, before answering            |
//! | `promote.manifest` | promoting follower, before bumping the manifest |
//! | `promote.swap`   | promoting follower, before flipping its role      |
//! | `demote.patrol`  | promoted leader, before each old-leader probe     |
//!
//! A *kill-at-phase* is orchestrated from the test side: arm a
//! `DelayMs` rule on the phase's point, wait for [`hits`] to show the
//! victim is inside it, and shut the victim down — the peer dies
//! exactly mid-phase, deterministically.
//!
//! Rules fire by visit count (`after` skips, `count` firings) and,
//! optionally, a seeded coin (`prob` under the plan's xorshift64* RNG)
//! — the same seed always drops the same visits, and the CI flake
//! guard runs the suite under two seeds to shake out
//! order-dependencies.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

/// What a matched rule does to the visiting operation.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultAction {
    /// Drop the operation: the hook errors and the visitor's normal
    /// failure path runs (a dropped poll, a dead connection).
    Drop,
    /// Stall the operation this long, then let it proceed (a slow or
    /// partitioned link; pair with a test-side kill for kill-at-phase).
    DelayMs(u64),
    /// At a byte-carrying point ([`hit_bytes`]), chop the tail off the
    /// payload and let the visitor trip over the damage; at a plain
    /// point, same as `Drop`.
    Truncate,
}

/// One scripted rule: after `after` visits of `point`, fire on up to
/// `count` of the following visits, each gated by a coin of bias
/// `prob` drawn from the plan's seeded RNG.
#[derive(Debug, Clone)]
pub struct FaultRule {
    /// Fault point this rule watches (table in the module docs).
    pub point: String,
    /// Visits of `point` to let pass before the rule becomes eligible.
    pub after: u64,
    /// Maximum firings; the rule is spent afterwards.
    pub count: u64,
    /// Probability an eligible visit fires (1.0 = every one). Drawn
    /// from the plan RNG, so a seed fixes the exact firing pattern.
    pub prob: f64,
    pub action: FaultAction,
}

impl FaultRule {
    /// An always-firing rule at `point` — the common deterministic case.
    pub fn every(point: &str, action: FaultAction) -> Self {
        Self { point: point.into(), after: 0, count: u64::MAX, prob: 1.0, action }
    }

    /// Fire exactly once, on the `after + 1`-th visit.
    pub fn once_after(point: &str, after: u64, action: FaultAction) -> Self {
        Self { point: point.into(), after, count: 1, prob: 1.0, action }
    }
}

/// A seeded set of rules; [`arm`] it, run the scenario, [`disarm`].
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Seed of the xorshift64* stream behind every `prob` coin (0 is
    /// remapped — xorshift has a fixed point at 0).
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

struct ArmedRule {
    rule: FaultRule,
    seen: u64,
    fired: u64,
}

struct Armed {
    rng: u64,
    rules: Vec<ArmedRule>,
    /// Visit counts per point, every point ever hit while armed — how a
    /// test waits for a victim to reach a phase.
    counts: Vec<(String, u64)>,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Armed>> = Mutex::new(None);

/// Install `plan` process-wide. Replaces any previous plan; visit
/// counts restart at zero.
pub fn arm(plan: FaultPlan) {
    let armed = Armed {
        rng: if plan.seed == 0 { 0x9E3779B97F4A7C15 } else { plan.seed },
        rules: plan
            .rules
            .into_iter()
            .map(|rule| ArmedRule { rule, seen: 0, fired: 0 })
            .collect(),
        counts: Vec::new(),
    };
    *PLAN.lock().unwrap() = Some(armed);
    ARMED.store(true, Ordering::Release);
}

/// Remove the armed plan; every later hit is free and cannot fire.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    *PLAN.lock().unwrap() = None;
}

/// How many times `point` has been visited since [`arm`] (0 when
/// disarmed) — the synchronization primitive for kill-at-phase tests.
pub fn hits(point: &str) -> u64 {
    if !ARMED.load(Ordering::Acquire) {
        return 0;
    }
    let plan = PLAN.lock().unwrap();
    plan.as_ref()
        .and_then(|p| {
            p.counts.iter().find(|(n, _)| n == point).map(|(_, c)| *c)
        })
        .unwrap_or(0)
}

fn xorshift(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x.wrapping_mul(0x2545F4914F6CDD1D)
}

/// Consult the plan for a visit of `point`. Returns the action to
/// perform, with any delay already slept (sleeping under the plan lock
/// would serialize unrelated points).
fn consult(point: &str) -> Option<FaultAction> {
    if !ARMED.load(Ordering::Acquire) {
        return None;
    }
    let mut fired = None;
    {
        let mut plan = PLAN.lock().unwrap();
        let Some(plan) = plan.as_mut() else { return None };
        match plan.counts.iter_mut().find(|(n, _)| n == point) {
            Some((_, c)) => *c += 1,
            None => plan.counts.push((point.to_string(), 1)),
        }
        let mut rng = plan.rng;
        for armed in &mut plan.rules {
            if armed.rule.point != point {
                continue;
            }
            armed.seen += 1;
            if fired.is_some()
                || armed.seen <= armed.rule.after
                || armed.fired >= armed.rule.count
            {
                continue;
            }
            // A coin is drawn per eligible visit whether or not it
            // fires, so one seed fixes the whole pattern.
            let coin =
                (xorshift(&mut rng) >> 11) as f64 / (1u64 << 53) as f64;
            if coin < armed.rule.prob {
                armed.fired += 1;
                fired = Some(armed.rule.action.clone());
            }
        }
        plan.rng = rng;
    }
    if let Some(FaultAction::DelayMs(ms)) = &fired {
        std::thread::sleep(std::time::Duration::from_millis(*ms));
    }
    fired
}

/// Visit a fault point. `Err` when an armed rule drops the operation;
/// a delay has already been served.
pub fn hit(point: &str) -> Result<()> {
    match consult(point) {
        None | Some(FaultAction::DelayMs(_)) => Ok(()),
        Some(FaultAction::Drop) | Some(FaultAction::Truncate) => {
            bail!("fault injected: {point} dropped")
        }
    }
}

/// Visit a byte-carrying fault point. `Truncate` chops the tail off
/// `bytes` (at least one byte, at most half) and lets the visitor
/// proceed into the damage — downstream validation must catch it.
pub fn hit_bytes(point: &str, bytes: &mut Vec<u8>) -> Result<()> {
    match consult(point) {
        None | Some(FaultAction::DelayMs(_)) => Ok(()),
        Some(FaultAction::Drop) => bail!("fault injected: {point} dropped"),
        Some(FaultAction::Truncate) => {
            let cut = (bytes.len() / 2).max(1).min(bytes.len());
            bytes.truncate(bytes.len() - cut);
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; tests that arm it serialize here
    // (the integration suites each run in their own process).
    static SERIAL: Mutex<()> = Mutex::new(());

    #[test]
    fn disarmed_hits_are_free_and_uncounted() {
        let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
        disarm();
        assert!(hit("sync.fetch").is_ok());
        assert_eq!(hits("sync.fetch"), 0);
    }

    #[test]
    fn rules_fire_by_visit_window() {
        let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
        arm(FaultPlan {
            seed: 1,
            rules: vec![FaultRule {
                point: "sync.fetch".into(),
                after: 2,
                count: 2,
                prob: 1.0,
                action: FaultAction::Drop,
            }],
        });
        let outcomes: Vec<bool> =
            (0..6).map(|_| hit("sync.fetch").is_ok()).collect();
        assert_eq!(outcomes, [true, true, false, false, true, true]);
        assert_eq!(hits("sync.fetch"), 6);
        assert_eq!(hits("sync.adopt"), 0);
        disarm();
    }

    #[test]
    fn seeded_coins_are_reproducible_and_seed_sensitive() {
        let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
        let pattern = |seed: u64| -> Vec<bool> {
            arm(FaultPlan {
                seed,
                rules: vec![FaultRule {
                    point: "p".into(),
                    after: 0,
                    count: u64::MAX,
                    prob: 0.5,
                    action: FaultAction::Drop,
                }],
            });
            let got = (0..64).map(|_| hit("p").is_ok()).collect();
            disarm();
            got
        };
        let a1 = pattern(7);
        let a2 = pattern(7);
        assert_eq!(a1, a2, "same seed, same drops");
        let b = pattern(8);
        assert_ne!(a1, b, "different seed, different drops");
        assert!(a1.iter().any(|ok| *ok) && a1.iter().any(|ok| !*ok));
    }

    #[test]
    fn truncate_damages_bytes_without_dropping() {
        let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
        arm(FaultPlan {
            seed: 1,
            rules: vec![FaultRule::once_after(
                "sync.files",
                0,
                FaultAction::Truncate,
            )],
        });
        let mut bytes = vec![9u8; 100];
        assert!(hit_bytes("sync.files", &mut bytes).is_ok());
        assert!(bytes.len() < 100, "tail chopped");
        let len = bytes.len();
        assert!(hit_bytes("sync.files", &mut bytes).is_ok());
        assert_eq!(bytes.len(), len, "rule spent after one firing");
        // at a plain point the same action is a drop
        arm(FaultPlan {
            seed: 1,
            rules: vec![FaultRule::every("x", FaultAction::Truncate)],
        });
        assert!(hit("x").is_err());
        disarm();
    }

    #[test]
    fn delay_stalls_then_proceeds() {
        let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
        arm(FaultPlan {
            seed: 1,
            rules: vec![FaultRule::every("slow", FaultAction::DelayMs(30))],
        });
        let t0 = std::time::Instant::now();
        assert!(hit("slow").is_ok());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(25));
        disarm();
    }
}
