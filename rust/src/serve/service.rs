//! The in-process service: `S` independent shard fleets (workers + queue +
//! blob + reducer + [`SnapshotStore`]) behind a coarse-quantizer
//! [`Router`], organised into **router epochs**.
//!
//! Training topology per shard is exactly the cloud runtime's (eq. 9 /
//! CloudDALVQ): `M` worker threads exchange displacements through the
//! shard's queue and blob services without barriers, and a dedicated
//! reducer folds whatever arrives next, epoch-swapping immutable snapshots
//! into the shard's store. Shards never synchronize with each other —
//! Patra's asynchronous-LVQ analysis holds per shard, and the router is
//! the only cross-shard structure.
//!
//! The router is frozen *within* an epoch, not for the process lifetime:
//! the whole partition — coarse centroids plus the `S` fleets they route
//! to — lives in one [`Epoch`] value behind an `Arc`-swapped cell, the
//! same publication discipline [`SnapshotStore`] uses for codebooks. A
//! **rebalance** quiesces the current epoch's fleets (the read path keeps
//! answering from their final published snapshots), flushes a checkpoint,
//! re-partitions the *durable* state offline
//! ([`crate::persist::rebalance`]: router retrained from the checkpointed
//! codebooks weighted by observed ingest, prototype rows migrated across
//! the shard files), restarts fresh fleets from the rewritten directory,
//! and publishes the new epoch — queries are served from the old epoch
//! until the swap, so the read path never drops. A skew monitor can
//! auto-trigger this when per-shard ingest counters diverge
//! (`rebalance_skew`), which is Kamp et al.'s adapt-the-partition-to-load
//! argument operationalised.
//!
//! With `shards = 1` the service collapses to the original single-fleet
//! deployment, bit-for-bit (same seeds, same data order).
//!
//! ## Replication
//!
//! A service started with `follow: Some(leader_addr)` is a **read-only
//! follower**: instead of spawning training fleets it restores the
//! leader's shipped checkpoint bundle into a fleetless epoch, serves the
//! full read surface from it, and keeps re-syncing — a background thread
//! polls the leader's `FetchState` op every `sync_every_ms` and
//! atomically adopts each new checkpoint generation by the same
//! epoch-swap publication a rebalance uses, so in-flight reads never
//! drop and a leader rebalance's bumped `router_version` flows through
//! transparently. Writes (`ingest`/`checkpoint`/`rebalance`) answer
//! `NotLeader` with the leader's address. This is the paper's final
//! scheme applied to serving: no inter-machine synchronization, only
//! asynchronous, delayed state exchange — and Patra's delayed-view
//! analysis is exactly why a follower lagging `sync_lag_folds` behind
//! still answers from a valid iterate.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Barrier, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::cloud::{
    BlobHandle, BlobService, DeltaMsg, LatencyInjector, QueueService,
};
use crate::config::{ExperimentConfig, ServeConfig};
use crate::data::Dataset;
use crate::obs::{
    Counter, Gauge, Histogram, SpanRec, Telemetry, TelemetrySnapshot,
    TraceSink, NO_PARENT,
};
use crate::persist::{
    self, CheckpointSpec, Checkpointer, Manifest, RestoredState, RouterState,
    ShardState,
};
use crate::vq::{init_codebook, nearest_batch_into, Codebook};

use super::client::Client;
use super::faults;
use super::protocol::{StateFile, StateShipment, FETCH_ANY_GENERATION};
use super::router::Router;
use super::snapshot::{Snapshot, SnapshotStore};
use super::worker::{run_serve_worker, ServeWorkerOutcome, ServeWorkerParams};

/// Per-attempt connect timeout of a follower's sync poll (bounded so a
/// dead leader costs one short stall per poll, not a hang).
const SYNC_CONNECT_TIMEOUT: Duration = Duration::from_secs(1);

/// Payload budget of one `FetchState`/`FetchChunk` frame: just under
/// the wire's 64 MiB frame cap, leaving a megabyte of headroom for the
/// shipment envelope (names, offsets, counts). A cut that outgrows this
/// ships as `chunks > 1` numbered frames.
const SHIP_CHUNK_BUDGET: usize = 63 << 20;

/// Generations the delta index remembers. A requester whose adopted
/// generation aged out of the index simply gets a full bundle — the
/// index is a bandwidth optimisation, never a correctness input.
const SHIP_HISTORY: usize = 32;

/// How far a promotion jumps the checkpoint generation past the adopted
/// one. A fencing margin, not a +1: the dead leader's on-disk manifest
/// may have advanced past the last generation it *shipped*, and a
/// returning leader only accepts demotion under a strictly higher
/// generation — the jump dwarfs any drift a miss window could produce.
const PROMOTE_GENERATION_JUMP: u64 = 1 << 20;

// The journal ring capacity comes from `ServeConfig::journal_capacity`
// (default 256, validated >= 16); it is also the event budget of a
// `--metrics-file` snapshot, while the wire's `Metrics` op asks for its
// own count.

/// Pre-resolved handles for one wire op's hot-path metrics.
pub(crate) struct OpTel {
    /// Requests dispatched (also `StatsReply`'s per-op counters).
    pub requests: Arc<Counter>,
    /// End-to-end handler latency, µs.
    pub total_us: Arc<Histogram>,
}

/// The front-end's pre-resolved telemetry handles: the registry lookups
/// happen once here, at startup, so recording a request costs a handful
/// of relaxed atomic ops and no name resolution.
pub(crate) struct ServeTel {
    /// Request frame decode latency, µs.
    pub decode_us: Arc<Histogram>,
    /// Response frame encode latency, µs.
    pub encode_us: Arc<Histogram>,
    /// Coarse-quantizer routing stage of a read query, µs per batch.
    pub route_us: Arc<Histogram>,
    /// Shard-snapshot scan stage of a read query, µs per batch.
    pub scan_us: Arc<Histogram>,
    /// Requests that exceeded `ServeConfig::slow_query_us`.
    pub slow_queries: Arc<Counter>,
    /// Points per drained micro-batch of the cross-request coalescer
    /// (a count, not µs; one sample per drain, including batches of one
    /// request). Empty unless `--batch-window-us` arms the batcher.
    pub batch_size: Arc<Histogram>,
    /// Microseconds a coalesced request waited in the batcher queue,
    /// from enqueue to the drain that answered it.
    pub batch_wait_us: Arc<Histogram>,
    pub op_encode: OpTel,
    pub op_nearest: OpTel,
    pub op_distortion: OpTel,
    pub op_ingest: OpTel,
    /// Everything else (stats, checkpoint, rebalance, fetch-state,
    /// metrics itself).
    pub op_other: OpTel,
    /// Connections currently open on the event-loop front-end.
    pub conn_active: Arc<Gauge>,
    /// Connections accepted, service lifetime.
    pub conn_accepted: Arc<Counter>,
    /// Requests refused by admission control (every `Throttled` answer:
    /// rate quota, in-flight cap, or brownout shedding).
    pub conn_rejected: Arc<Counter>,
    /// One reactor cycle servicing readiness events, µs (the poll wait
    /// itself is excluded — this is time the loop spent working, not
    /// parked).
    pub readiness_us: Arc<Histogram>,
}

impl ServeTel {
    fn new(t: &Telemetry) -> ServeTel {
        let op = |name: &str| OpTel {
            requests: t.counter(&format!("op.{name}.requests")),
            total_us: t.histogram(&format!("op.{name}.total_us")),
        };
        ServeTel {
            decode_us: t.histogram("frame.decode_us"),
            encode_us: t.histogram("frame.encode_us"),
            route_us: t.histogram("query.route_us"),
            scan_us: t.histogram("query.scan_us"),
            slow_queries: t.counter("slow_queries"),
            batch_size: t.histogram("batch.size"),
            batch_wait_us: t.histogram("batch.wait_us"),
            op_encode: op("encode"),
            op_nearest: op("nearest"),
            op_distortion: op("distortion"),
            op_ingest: op("ingest"),
            op_other: op("other"),
            conn_active: t.gauge("conn.active"),
            conn_accepted: t.counter("conn.accepted"),
            conn_rejected: t.counter("conn.rejected"),
            readiness_us: t.histogram("io.readiness_us"),
        }
    }
}

/// What [`VqService::query_nearest_timed`] returns: the answers of
/// [`VqService::query_nearest_probed`] plus the per-stage timings the
/// telemetry plane and the slow-query log report.
pub(crate) struct TimedQuery {
    pub version: u64,
    pub codes: Vec<u32>,
    pub dists: Vec<f32>,
    /// Microseconds routing the batch through the coarse quantizer.
    pub route_us: u64,
    /// Microseconds scanning the probed shards' snapshots.
    pub scan_us: u64,
}

/// Live counters, shared between the fleets and the front-end. These are
/// service-lifetime totals — they survive router-epoch swaps (the
/// per-shard counters on each epoch's fleets reset at a rebalance,
/// because shard identity changes with the partition).
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Ingested points accepted into worker queues (all shards).
    pub ingested: AtomicU64,
    /// Ingested points shed because a worker's queue was full (or because
    /// the owning epoch was mid-migration).
    pub ingest_shed: AtomicU64,
    /// Queries answered (all read ops; maintained by the front-end).
    pub queries: AtomicU64,
    /// Fold clock across every shard's reducer. Within an epoch this
    /// counts actual deltas folded; a rebalance advances it so it stays
    /// `>=` the summed published versions (migrated fleets resume at the
    /// max of the old shard versions).
    pub merges: AtomicU64,
    /// Completed rebalances (router-epoch swaps) this process lifetime.
    pub rebalances: AtomicU64,
}

/// A point-in-time view of [`ServeCounters`] plus service shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeStats {
    /// Sum of per-shard snapshot versions (monotone — including across
    /// rebalances; the global freshness clock of the service).
    pub version: u64,
    /// Total prototypes across shards.
    pub kappa: usize,
    /// Prototype dimension.
    pub dim: usize,
    /// Total workers across all shards.
    pub workers: usize,
    /// Shard count of the serving epoch.
    pub shards: usize,
    /// Shards probed per query point.
    pub probe_n: usize,
    /// Partition version of the serving router epoch (0 = bootstrap,
    /// bumped by every rebalance).
    pub router_version: u64,
    /// Completed rebalances this process lifetime.
    pub rebalances: u64,
    /// Fold clock, all shards (>= version; they differ when reducers
    /// publish every `publish_every` folds).
    pub merges: u64,
    /// Points accepted into worker queues, service lifetime.
    pub ingested: u64,
    /// Points shed, service lifetime.
    pub ingest_shed: u64,
    /// Read requests answered, service lifetime.
    pub queries: u64,
    /// Published snapshot version per shard.
    pub shard_versions: Vec<u64>,
    /// Reducer fold count per shard.
    pub shard_merges: Vec<u64>,
    /// Points accepted per shard during the current router epoch — what
    /// the skew monitor (and the rebalance retrainer) read.
    pub shard_ingest: Vec<u64>,
    /// Points shed per shard during the current router epoch.
    pub shard_shed: Vec<u64>,
    /// Durable state directory (`None` when the service runs without
    /// persistence).
    pub state_dir: Option<String>,
    /// Last checkpointed version per shard (empty without persistence).
    pub last_checkpoint: Vec<u64>,
    /// Replication role: `"leader"` or `"follower"`.
    pub role: String,
    /// Leader address this service replicates (`None` on a leader).
    pub leader_addr: Option<String>,
    /// Follower freshness: leader's live version at the last sync poll
    /// minus the version served here (0 on a leader).
    pub sync_lag_folds: u64,
    /// Milliseconds since the last successful sync poll (0 on a leader).
    pub last_sync_ms: u64,
    /// How the last adopted bundle arrived on a follower: `"delta"` or
    /// `"full"`; empty on a leader (or before the first adoption).
    pub sync_source: String,
    /// Milliseconds since the service came up.
    pub uptime_ms: u64,
    /// `Encode` requests handled by the front-end.
    pub op_encode: u64,
    /// `Nearest` requests handled by the front-end.
    pub op_nearest: u64,
    /// `Distortion` requests handled by the front-end.
    pub op_distortion: u64,
    /// `Ingest` requests handled by the front-end.
    pub op_ingest: u64,
}

/// What one shard's fleet reports at shutdown.
#[derive(Debug)]
pub struct ShardOutcome {
    /// Shard index within the epoch.
    pub shard: usize,
    /// The shard reducer's fold clock at join (includes any restored or
    /// migrated base).
    pub merges: u64,
    /// The shard's final shared codebook (`kappa/S` prototypes).
    pub final_shared: Codebook,
}

/// What the whole service reports at shutdown.
#[derive(Debug)]
pub struct ServeOutcome {
    /// Every worker of the final epoch, shard-major order.
    pub workers: Vec<ServeWorkerOutcome>,
    /// Summed shard fold clocks at shutdown.
    pub merges: u64,
    /// The global codebook: shard codebooks concatenated in shard order
    /// (row `s * kappa/S + j` is shard `s`'s prototype `j`, matching the
    /// global codes queries return).
    pub final_shared: Codebook,
    /// Per-shard outcomes, shard order.
    pub shards: Vec<ShardOutcome>,
}

/// What a completed rebalance reports (the wire's `RebalanceAck`).
#[derive(Debug, Clone, PartialEq)]
pub struct RebalanceOutcome {
    /// The bumped partition version now serving.
    pub router_version: u64,
    /// Prototype rows that changed shard.
    pub moved_rows: u64,
    /// Per-shard versions the migrated fleets resumed at.
    pub shard_versions: Vec<u64>,
    /// Old→new global-code remap (`remap[old] = new`): clients holding
    /// codes from the previous epoch translate through this table.
    pub remap: Vec<u32>,
}

/// One shard's training fleet handles — taken exactly once at quiesce.
struct Fleet {
    workers: Vec<JoinHandle<Result<ServeWorkerOutcome>>>,
    reducer: JoinHandle<Result<(u64, Codebook)>>,
    /// Held so the queue stays open until shutdown drops it.
    queue_template: crate::cloud::QueueHandle,
}

/// One shard: an independent eq.-9 fleet plus its publication store and
/// per-epoch load counters.
struct ShardFleet {
    store: Arc<SnapshotStore>,
    merges: Arc<AtomicU64>,
    /// Points accepted by this shard during the current router epoch
    /// (`Arc`: the checkpointer persists it next to the codebook so the
    /// rebalance retrainer can weight this shard's rows by it).
    ingested: Arc<AtomicU64>,
    /// Points routed here but shed during the current router epoch.
    shed: Arc<AtomicU64>,
    /// Ingest batches sent to this shard's workers and not yet absorbed
    /// (the telemetry plane's `shard.<s>.queue_depth`; incremented per
    /// accepted batch here, decremented by the receiving worker).
    queue_depth: Arc<Gauge>,
    /// Cloned under a short lock per ingest call; cleared at quiesce.
    ingest_txs: Mutex<Vec<mpsc::SyncSender<Vec<f32>>>>,
    ingest_cursor: AtomicUsize,
    fleet: Mutex<Option<Fleet>>,
}

/// One router epoch: a frozen coarse partition plus the `S` fleets
/// serving it. The whole value sits behind an `Arc`-swapped cell in
/// [`VqService`], so every query resolves routing and shard snapshots
/// against one consistent partition even while a rebalance publishes the
/// next epoch.
struct Epoch {
    router: Router,
    router_version: u64,
    shards: Vec<ShardFleet>,
    /// Stops THIS epoch's fleets (the service-level `closing` flag is
    /// separate: a rebalance stops an epoch without closing the service).
    stop: Arc<AtomicBool>,
    go: Arc<AtomicBool>,
    /// Per-shard published version at epoch start — the monitor's floor
    /// for "folds trained in this epoch".
    base_versions: Vec<u64>,
}

/// Seed state for one shard fleet of a new epoch.
struct ShardSeed {
    w0: Codebook,
    /// Version the fleet resumes publishing from (0 on a cold start).
    version: u64,
    /// Initial schedule cursor per worker (exchange-aligned).
    t0: u64,
    ingested: u64,
    shed: u64,
}

/// The running service. Queries go through the `query_*` methods (which
/// route through the current epoch's coarse quantizer); ingestion through
/// [`VqService::ingest`]; the TCP front-end ([`super::Server`]) is a thin
/// adapter over exactly these methods.
///
/// Everything lifecycle-shaped takes `&self` (the service is shared
/// behind an `Arc` with connection handlers and the skew monitor), so
/// callers never need to reclaim unique ownership from in-flight
/// connections.
pub struct VqService {
    /// Deployment config, kept so a rebalance can respawn fleets.
    cfg: ExperimentConfig,
    serve: ServeConfig,
    /// The serving epoch; swapped by `rebalance`.
    epoch: Mutex<Arc<Epoch>>,
    counters: Arc<ServeCounters>,
    dim: usize,
    /// Total prototypes across shards.
    kappa: usize,
    /// Prototypes per shard (`kappa / S`).
    kappa_shard: usize,
    workers_per_shard: usize,
    probe_n: usize,
    /// The service is shutting down (monitor exits, rebalance refuses,
    /// ingest errors instead of shedding).
    closing: Arc<AtomicBool>,
    /// Durable state directory (None = no persistence).
    state_dir: Option<PathBuf>,
    /// Last checkpointed version per shard (always `S`-sized; only
    /// meaningful with `state_dir`).
    last_checkpoint: Arc<Vec<AtomicU64>>,
    /// The background checkpointer of the current epoch; swapped by
    /// `rebalance`, taken at shutdown.
    checkpointer: Mutex<Option<Checkpointer>>,
    /// Serializes rebalances against each other and against shutdown.
    lifecycle: Mutex<()>,
    /// The skew monitor thread, when auto-rebalance is configured.
    monitor: Mutex<Option<JoinHandle<()>>>,
    /// The checkpoint-generation clock of the state dir: mirrors the
    /// generation the on-disk manifest currently carries. Shared with
    /// the checkpointer (which bumps it on every manifest write) and
    /// re-seeded by rebalances; what `FetchState` pollers compare.
    state_generation: Arc<AtomicU64>,
    /// The delta index: `(generation, router_version, shard_versions)`
    /// of recently cut or adopted bundles, so a `FetchState` poll whose
    /// `have_generation` is remembered ships only the shard files whose
    /// version advanced. Bounded ([`SHIP_HISTORY`]); a miss means a full
    /// bundle, never an error.
    ship_history: Mutex<Vec<(u64, u64, Vec<u64>)>>,
    /// `Some(new leader)` once a `Demote` fenced this leader off: writes
    /// and state fetches answer `NotLeader` there (set only on services
    /// started as leaders; a follower re-points [`FollowerCtl`] instead).
    demoted: Mutex<Option<String>>,
    /// The address this service is reachable at (set by the TCP
    /// front-end when it binds) — what a promoted follower advertises
    /// in its `Demote` patrol.
    advertise: Mutex<Option<String>>,
    /// Follower-mode state (`None` on a leader).
    follower: Option<FollowerCtl>,
    /// The telemetry plane: metric registry + event journal + uptime.
    /// Shared with the checkpointer (journal) and the metrics-file
    /// writer; exposed over the wire by the `Metrics` op.
    telemetry: Arc<Telemetry>,
    /// Pre-resolved hot-path handles over `telemetry`.
    tel: ServeTel,
    /// The `--metrics-file` writer thread, when configured; joined at
    /// shutdown.
    metrics_writer: Mutex<Option<JoinHandle<()>>>,
}

/// Everything follower-specific: who the leader is, the sync cadence,
/// and the freshness the sync loop publishes for `Stats`.
struct FollowerCtl {
    /// `host:port` of the current sync source (the `--follow` value at
    /// start — also what `NotLeader` redirects clients to). Mutable:
    /// a `NotLeader` bounce mid-sync or a `Demote` re-points it.
    leader_addr: Mutex<String>,
    /// Pause between sync polls.
    sync_every: Duration,
    /// Leader's live version at the last poll minus the version served
    /// here (what `ServeStats::sync_lag_folds` reports).
    lag_folds: AtomicU64,
    /// When the last successful poll completed.
    last_sync: Mutex<Instant>,
    /// Raw file set of the last adopted bundle — the base a shipped
    /// delta merges into ([`persist::apply_delta`]).
    held: Mutex<Vec<(String, Vec<u8>)>>,
    /// `"delta"` or `"full"`: how the last adoption arrived (what
    /// `ServeStats::sync_source` reports).
    sync_source: Mutex<String>,
    /// Consecutive failed sync polls; reset by every success. Crossing
    /// `miss_threshold` (when armed) triggers promotion.
    misses: AtomicU64,
    /// The next poll must fetch the full bundle (set when a delta
    /// failed to apply — re-asking for the same delta would loop on the
    /// same damage forever).
    force_full: AtomicBool,
    /// This follower promoted itself to leader (automatic failover):
    /// the sync loop becomes a demote patrol, `NotLeader` redirects
    /// stop, and `FetchState` serves peers from the mirror dir.
    promoted: AtomicBool,
    /// The demote patrol reached the old leader and it acknowledged;
    /// nothing left to patrol.
    patrol_done: AtomicBool,
    /// The sync-loop thread; taken at shutdown (an empty slot after
    /// `start` means the service was already shut down).
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl VqService {
    /// Build the router and every shard fleet, then start serving. Blocks
    /// until all `S * M` workers have built their engines and passed the
    /// ready barrier, so the first query already sees a live system.
    /// Returns an `Arc` because the service is inherently shared: the
    /// skew monitor (when `rebalance_skew` is set) holds a weak handle.
    ///
    /// With `serve.follow` set this instead starts a **read-only
    /// follower**: no fleets are spawned — the initial epoch is restored
    /// from the leader's shipped checkpoint bundle (so the leader must
    /// be up and running with a `--state-dir`), and a sync thread keeps
    /// adopting new checkpoint generations.
    pub fn start(
        cfg: &ExperimentConfig,
        serve: &ServeConfig,
    ) -> Result<Arc<VqService>> {
        cfg.validate()?;
        serve.validate(cfg)?;
        if serve.follow.is_some() {
            return Self::start_follower(cfg, serve);
        }

        let dim = cfg.dim();
        let s_count = serve.shards;
        let kappa_shard = cfg.vq.kappa / s_count;
        let telemetry = Telemetry::new(serve.journal_capacity);
        telemetry.tracer().configure(serve.trace_sample, serve.slow_query_us);

        // Warm restart: load and validate durable state before anything
        // is built (a mismatched state dir must fail here, loudly, not
        // seed a fleet with the wrong shapes).
        let restored = match &serve.state_dir {
            Some(dir) => load_restore(dir, cfg, serve)?,
            None => None,
        };

        // The coarse quantizer: restored verbatim on a warm start (a
        // retrained router would repartition the space and orphan every
        // saved shard codebook — rebalancing is an explicit, offline
        // operation on the state dir, never a startup side effect);
        // otherwise a short k-means pass over a bootstrap sample (prefix
        // of the dataset — already i.i.d. from the mixture), frozen for
        // this epoch.
        let (router, router_version) = match &restored {
            Some(r) => (
                Router::from_centroids(r.router.centroids.clone()),
                r.manifest.router_version,
            ),
            None => {
                // The bootstrap sample is the dataset prefix (stream 0 is
                // sequential, so generating just the prefix yields the
                // same bytes without materialising the full dataset —
                // spawn_epoch builds that once, for the worker corpora).
                let sample_pts = serve.router_sample.min(cfg.data.n_total);
                let sample =
                    cfg.data.mixture.generate(sample_pts, cfg.seed, 0);
                (
                    Router::train(
                        &sample,
                        dim,
                        s_count,
                        serve.router_iters,
                        cfg.seed,
                    ),
                    0,
                )
            }
        };

        let counters = Arc::new(ServeCounters::default());
        let seeds = restored
            .as_ref()
            .map(|r| seeds_from_restored(r, serve, cfg.m));
        // The service-wide fold clock resumes from the saved versions.
        if let Some(seeds) = &seeds {
            let base: u64 = seeds.iter().map(|s| s.version).sum();
            counters.merges.fetch_add(base, Ordering::Relaxed);
        }
        let epoch = spawn_epoch(
            cfg,
            serve,
            &counters,
            &telemetry,
            router,
            router_version,
            seeds,
            serve.start_paused,
        )?;

        // Persistence: on a cold start write the full initial state
        // (router + shard files + manifest) so the directory is
        // restorable from the first moment, then hand the shard stores to
        // the background checkpointer.
        let last_checkpoint: Arc<Vec<AtomicU64>> = Arc::new(
            (0..s_count)
                .map(|s| {
                    AtomicU64::new(
                        restored.as_ref().map_or(0, |r| r.shards[s].version),
                    )
                })
                .collect(),
        );
        // The generation clock resumes from what the manifest on disk
        // carries (0 on a cold start — written just below), so pollers
        // see a strictly advancing sequence across restarts.
        let state_generation = Arc::new(AtomicU64::new(
            restored.as_ref().map_or(0, |r| r.manifest.generation),
        ));
        let checkpointer = match &serve.state_dir {
            Some(dir) => {
                if restored.is_none() {
                    write_initial_state(dir, &epoch, cfg, serve, 0)?;
                }
                Some(spawn_checkpointer(
                    dir,
                    &epoch,
                    &last_checkpoint,
                    &state_generation,
                    &telemetry,
                    cfg,
                    serve,
                ))
            }
            None => None,
        };

        let service = Arc::new(VqService {
            cfg: cfg.clone(),
            serve: serve.clone(),
            epoch: Mutex::new(Arc::new(epoch)),
            counters,
            dim,
            kappa: cfg.vq.kappa,
            kappa_shard,
            workers_per_shard: cfg.m,
            probe_n: serve.probe_n,
            closing: Arc::new(AtomicBool::new(false)),
            state_dir: serve.state_dir.clone(),
            last_checkpoint,
            checkpointer: Mutex::new(checkpointer),
            lifecycle: Mutex::new(()),
            monitor: Mutex::new(None),
            state_generation,
            ship_history: Mutex::new(Vec::new()),
            demoted: Mutex::new(None),
            advertise: Mutex::new(None),
            follower: None,
            tel: ServeTel::new(&telemetry),
            telemetry,
            metrics_writer: Mutex::new(None),
        });
        if serve.rebalance_skew > 0.0 {
            let handle = spawn_monitor(&service);
            *service.monitor.lock().unwrap_or_else(|e| e.into_inner()) = Some(handle);
        }
        service.start_metrics_writer();
        Ok(service)
    }

    /// Start a read-only follower of the leader at `serve.follow`:
    /// bootstrap-fetch the leader's full checkpoint bundle, adopt it as
    /// the serving epoch (no fleets — the stores hold the shipped
    /// codebooks verbatim), optionally mirror it into this process's own
    /// `state_dir`, and spawn the sync loop. The deployment **shape**
    /// (shards, kappa, dim) comes from the leader's manifest, not from
    /// the local config — a follower serves whatever its leader serves.
    fn start_follower(
        cfg: &ExperimentConfig,
        serve: &ServeConfig,
    ) -> Result<Arc<VqService>> {
        let leader_addr = serve
            .follow
            .clone()
            .expect("start_follower requires serve.follow");
        let mut client =
            Client::connect_with(leader_addr.as_str(), SYNC_CONNECT_TIMEOUT, 2)
                .with_context(|| {
                    format!("follower bootstrap: reaching leader {leader_addr}")
                })?;
        let ship = client
            .fetch_state(FETCH_ANY_GENERATION)
            .with_context(|| {
                format!(
                    "follower bootstrap: fetching state from {leader_addr} \
                     (is the leader running with --state-dir?)"
                )
            })?;
        // A bootstrap fetch may have bounced off a follower or a
        // demoted leader: whoever actually answered is the sync source.
        let leader_addr = client.redirected_to().unwrap_or(leader_addr);
        let files = shipped_files(ship.files);
        let restored = persist::decode_bundle(&files)
            .context("follower bootstrap: decoding the shipped bundle")?;
        if let Some(dir) = &serve.state_dir {
            persist::write_bundle(dir, &files).with_context(|| {
                format!("mirroring the bundle into {}", dir.display())
            })?;
        }
        let m = restored.manifest.clone();
        let counters = Arc::new(ServeCounters::default());
        let telemetry = Telemetry::new(serve.journal_capacity);
        telemetry.tracer().configure(serve.trace_sample, serve.slow_query_us);
        telemetry
            .counter("sync.full_bytes")
            .add(files.iter().map(|(_, b)| b.len() as u64).sum());
        let epoch = follower_epoch(&restored, &telemetry);
        let adopted: u64 = restored.shards.iter().map(|s| s.version).sum();
        counters.merges.store(adopted, Ordering::Relaxed);
        telemetry.journal().info(
            "sync.adopt",
            format!(
                "bootstrap: adopted generation {} at version {adopted} \
                 (router v{}) from {leader_addr}",
                ship.generation, m.router_version
            ),
        );
        let last_checkpoint: Arc<Vec<AtomicU64>> = Arc::new(
            restored
                .shards
                .iter()
                .map(|s| AtomicU64::new(s.version))
                .collect(),
        );
        let service = Arc::new(VqService {
            cfg: cfg.clone(),
            serve: serve.clone(),
            epoch: Mutex::new(Arc::new(epoch)),
            counters,
            dim: m.dim,
            kappa: m.kappa,
            kappa_shard: m.kappa / m.shards,
            workers_per_shard: 0,
            // Manifest validation guarantees shards >= 1, so the clamp
            // bounds are always ordered.
            probe_n: serve.probe_n.clamp(1, m.shards),
            closing: Arc::new(AtomicBool::new(false)),
            state_dir: serve.state_dir.clone(),
            last_checkpoint,
            checkpointer: Mutex::new(None),
            lifecycle: Mutex::new(()),
            monitor: Mutex::new(None),
            state_generation: Arc::new(AtomicU64::new(ship.generation)),
            ship_history: Mutex::new(Vec::new()),
            demoted: Mutex::new(None),
            advertise: Mutex::new(None),
            follower: Some(FollowerCtl {
                leader_addr: Mutex::new(leader_addr),
                sync_every: Duration::from_millis(serve.sync_every_ms.max(1)),
                lag_folds: AtomicU64::new(
                    ship.leader_version.saturating_sub(adopted),
                ),
                last_sync: Mutex::new(Instant::now()),
                held: Mutex::new(files),
                sync_source: Mutex::new("full".to_string()),
                misses: AtomicU64::new(0),
                force_full: AtomicBool::new(false),
                promoted: AtomicBool::new(false),
                patrol_done: AtomicBool::new(false),
                thread: Mutex::new(None),
            }),
            tel: ServeTel::new(&telemetry),
            telemetry,
            metrics_writer: Mutex::new(None),
        });
        // Seed the delta index with the adopted cut, so this follower
        // can itself ship deltas down the tree (and promote cheaply).
        service.remember_versions(
            ship.generation,
            m.router_version,
            m.shard_versions.clone(),
        );
        let follower = service.follower.as_ref().expect("just constructed");
        *follower.thread.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(spawn_follower_sync(&service));
        service.start_metrics_writer();
        Ok(service)
    }

    /// Spawn the `--metrics-file` writer when configured (both start
    /// paths call this exactly once, after the service `Arc` exists).
    fn start_metrics_writer(self: &Arc<Self>) {
        let Some(path) = self.serve.metrics_file.clone() else { return };
        let every = Duration::from_millis(self.serve.metrics_every_ms.max(1));
        let handle = spawn_metrics_writer(self, path, every);
        *self.metrics_writer.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(handle);
    }

    /// One follower sync poll: ask the leader for anything newer than
    /// the adopted generation; on a new bundle, validate it, optionally
    /// mirror it to the local state dir, build a fresh fleetless epoch
    /// and swap it in — in-flight reads keep their epoch, new reads see
    /// the new one, exactly the rebalance publication discipline.
    /// Returns `true` when a new generation was adopted.
    ///
    /// With tracing armed (`--trace-sample`), a sampled cycle records a
    /// `sync.cycle` trace and stamps its trace id on the `FetchState`
    /// call, so the leader's `state.cut` / `state.ship` spans come back
    /// over the wire and are grafted under `sync.fetch` — ONE trace
    /// spanning both processes. Only cycles that adopt files commit (an
    /// empty 25 ms poll is not worth a ring slot).
    fn sync_once(&self) -> Result<bool> {
        let t0 = Instant::now();
        let f = self
            .follower
            .as_ref()
            .ok_or_else(|| anyhow!("sync_once on a leader"))?;
        let leader_addr =
            f.leader_addr.lock().unwrap_or_else(|e| e.into_inner()).clone();
        let tracer = self.telemetry.tracer();
        let mut tb = tracer.begin_at(t0);
        let root = match tb.as_mut() {
            Some(t) => t.begin("sync.cycle", NO_PARENT),
            None => NO_PARENT,
        };
        faults::hit("sync.fetch")?;
        let mut client = Client::connect_with(
            leader_addr.as_str(),
            SYNC_CONNECT_TIMEOUT,
            0,
        )?;
        // On a follower, `state_generation` IS the adopted generation
        // (there is no local checkpointer writing to it). After a failed
        // delta apply the next poll re-fetches the full bundle —
        // re-asking for the same delta would loop on the same damage.
        let have = if f.force_full.swap(false, Ordering::AcqRel) {
            FETCH_ANY_GENERATION
        } else {
            self.state_generation.load(Ordering::Acquire)
        };
        let mut fetch_ctx = None; // (fetch span id, its start offset µs)
        if let Some(t) = tb.as_mut() {
            let (hi, lo) = t.trace_id();
            let anchor = t.now_us();
            let fetch = t.begin("sync.fetch", root);
            client.trace_next(hi, lo, fetch);
            fetch_ctx = Some((fetch, anchor));
        }
        let ship = client.fetch_state(have)?;
        // A `NotLeader` bounce mid-fetch means the tree re-shaped under
        // us (a failover, a demoted relay): whoever actually answered
        // becomes the sync source from here on.
        if let Some(to) = client.redirected_to() {
            *f.leader_addr.lock().unwrap_or_else(|e| e.into_inner()) =
                to.clone();
            self.telemetry.journal().info(
                "sync.repoint",
                format!("sync source moved: {leader_addr} -> {to}"),
            );
        }
        if let (Some(t), Some((fetch, anchor))) = (tb.as_mut(), fetch_ctx) {
            // The leader's half of the trace, re-anchored at the moment
            // the RPC went out (its spans are relative to its own frame
            // arrival, which sits inside our fetch span).
            let remote: Vec<SpanRec> = client
                .take_server_spans()
                .into_iter()
                .map(|s| SpanRec {
                    id: s.id,
                    parent: s.parent,
                    name: s.name,
                    start_us: s.start_us,
                    dur_us: s.dur_us,
                })
                .collect();
            t.graft(fetch, anchor, &remote);
            t.end(fetch);
        }
        if ship.files.is_empty() {
            // Nothing new checkpointed; the poll still refreshes lag
            // (the leader's live version advanced under us). The trace
            // builder drops uncommitted here, on purpose.
            let lag = ship.leader_version.saturating_sub(self.version());
            f.lag_folds.store(lag, Ordering::Release);
            self.telemetry.gauge("sync.lag_folds").set(lag);
            *f.last_sync.lock().unwrap_or_else(|e| e.into_inner()) = Instant::now();
            return Ok(false);
        }
        // A stale peer (an old leader back from the dead, a lagging
        // relay) must never run the adopted state backwards.
        if have != FETCH_ANY_GENERATION && ship.generation < have {
            bail!(
                "sync source {leader_addr} shipped stale generation {} \
                 (this follower already adopted {have})",
                ship.generation
            );
        }
        let delta = ship.delta;
        let mut files = shipped_files(ship.files);
        if let Some((_, bytes)) = files.last_mut() {
            // One byte-carrying fault visit per shipment: an injected
            // truncation chews the tail file, and decode below must
            // catch the damage.
            faults::hit_bytes("sync.files", bytes)?;
        }
        self.telemetry
            .counter(if delta { "sync.delta_bytes" } else { "sync.full_bytes" })
            .add(files.iter().map(|(_, b)| b.len() as u64).sum());
        if delta {
            let held = f.held.lock().unwrap_or_else(|e| e.into_inner());
            match persist::apply_delta(&held, &files) {
                Ok(merged) => files = merged,
                Err(e) => {
                    f.force_full.store(true, Ordering::Release);
                    return Err(e).context(
                        "applying the shipped delta to the held bundle \
                         (the next poll re-fetches the full bundle)",
                    );
                }
            }
        }
        faults::hit("sync.decode")?;
        let decode_span =
            tb.as_mut().map(|t| t.begin("sync.decode", root));
        let restored = match persist::decode_bundle(&files) {
            Ok(r) => r,
            Err(e) => {
                if delta {
                    f.force_full.store(true, Ordering::Release);
                }
                return Err(e)
                    .context("decoding the leader's shipped bundle");
            }
        };
        if let (Some(t), Some(id)) = (tb.as_mut(), decode_span) {
            t.end(id);
        }
        let m = &restored.manifest;
        if m.kappa != self.kappa || m.dim != self.dim {
            bail!(
                "leader now ships kappa={} dim={} but this follower adopted \
                 kappa={} dim={} at bootstrap — the leader was redeployed \
                 with a different shape; restart the follower",
                m.kappa,
                m.dim,
                self.kappa,
                self.dim
            );
        }
        if m.shards != self.kappa / self.kappa_shard {
            bail!(
                "leader now ships {} shards but this follower adopted {} — \
                 restart the follower",
                m.shards,
                self.kappa / self.kappa_shard
            );
        }
        if let Some(dir) = &self.state_dir {
            faults::hit("sync.mirror")?;
            let mirror_span =
                tb.as_mut().map(|t| t.begin("sync.mirror", root));
            persist::write_bundle(dir, &files).with_context(|| {
                format!("mirroring the bundle into {}", dir.display())
            })?;
            if let (Some(t), Some(id)) = (tb.as_mut(), mirror_span) {
                t.end(id);
            }
        }
        faults::hit("sync.adopt")?;
        let adopt_span =
            tb.as_mut().map(|t| t.begin("sync.adopt", root));
        let epoch = follower_epoch(&restored, &self.telemetry);
        let adopted: u64 = restored.shards.iter().map(|s| s.version).sum();
        for (s, st) in restored.shards.iter().enumerate() {
            self.last_checkpoint[s].store(st.version, Ordering::Release);
        }
        *self.epoch.lock().unwrap_or_else(|e| e.into_inner()) = Arc::new(epoch);
        // The fold clock mirrors the adopted versions (max: a bundle
        // re-shipping an old generation after a leader restore must not
        // run the clock backwards).
        self.counters.merges.fetch_max(adopted, Ordering::AcqRel);
        self.state_generation.store(ship.generation, Ordering::Release);
        // Remember the adopted cut so this follower can ship deltas down
        // the tree (and promote at a remembered generation).
        self.remember_versions(
            ship.generation,
            m.router_version,
            m.shard_versions.clone(),
        );
        let n_files = files.len();
        *f.held.lock().unwrap_or_else(|e| e.into_inner()) = files;
        let source = if delta { "delta" } else { "full" };
        *f.sync_source.lock().unwrap_or_else(|e| e.into_inner()) =
            source.to_string();
        let lag = ship.leader_version.saturating_sub(adopted);
        f.lag_folds.store(lag, Ordering::Release);
        self.telemetry.gauge("sync.lag_folds").set(lag);
        *f.last_sync.lock().unwrap_or_else(|e| e.into_inner()) = Instant::now();
        if let Some(mut t) = tb {
            if let Some(id) = adopt_span {
                t.end(id);
            }
            t.end(root);
            tracer.commit(t);
        }
        self.telemetry.journal().info(
            "sync.adopt",
            format!(
                "adopted generation {} at version {adopted} (router v{}, \
                 {n_files} files via {source}, lag {lag} folds) in {} ms",
                ship.generation,
                m.router_version,
                t0.elapsed().as_millis()
            ),
        );
        Ok(true)
    }

    /// `Some(leader address)` when this service redirects writes — a
    /// read-only follower (its current sync source) or a demoted leader
    /// (whoever fenced it). `None` on a serving leader, including a
    /// follower that promoted itself.
    pub fn follower_of(&self) -> Option<String> {
        if let Some(f) = &self.follower {
            if f.promoted.load(Ordering::Acquire) {
                return None;
            }
            return Some(
                f.leader_addr.lock().unwrap_or_else(|e| e.into_inner()).clone(),
            );
        }
        self.demoted.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Whether `FetchState` / `FetchChunk` can be answered here instead
    /// of redirected: leaders (and promoted followers) always — shipping
    /// still needs a `--state-dir`, which `fetch_state` checks; an
    /// un-promoted follower only when it mirrors adopted bundles into
    /// its own `--state-dir` (that is what makes it a relay of the
    /// fan-out tree); a demoted leader never (its cut is fenced stale).
    pub fn can_ship_state(&self) -> bool {
        match &self.follower {
            Some(_) => self.state_dir.is_some(),
            None => self
                .demoted
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .is_none(),
        }
    }

    /// Service-level twin of [`VqService::can_ship_state`] for callers
    /// that bypass the front-end guard (in-process tests, the CLI).
    fn shippable(&self) -> Result<()> {
        if let Some(f) = &self.follower {
            if self.state_dir.is_none() {
                bail!(
                    "this follower keeps no mirror --state-dir and cannot \
                     ship state; fetch it from the leader at {}",
                    f.leader_addr.lock().unwrap_or_else(|e| e.into_inner())
                );
            }
        } else if let Some(leader) =
            self.demoted.lock().unwrap_or_else(|e| e.into_inner()).clone()
        {
            bail!(
                "this leader was demoted; fetch state from the new leader \
                 at {leader}"
            );
        }
        Ok(())
    }

    /// Remember `(generation → router version, shard versions)` in the
    /// bounded delta index. Every consistent cut and every adoption
    /// passes through here, so any generation a requester can
    /// legitimately hold is indexable until it ages out.
    fn remember_versions(
        &self,
        generation: u64,
        router_version: u64,
        shard_versions: Vec<u64>,
    ) {
        let mut hist =
            self.ship_history.lock().unwrap_or_else(|e| e.into_inner());
        if hist.iter().any(|(g, _, _)| *g == generation) {
            return;
        }
        hist.push((generation, router_version, shard_versions));
        if hist.len() > SHIP_HISTORY {
            let drop = hist.len() - SHIP_HISTORY;
            hist.drain(..drop);
        }
    }

    /// The delta against a requester holding `have_generation`, when
    /// the index still remembers that cut and [`persist::delta_files`]
    /// agrees the router and shard shape are unchanged. `None` → ship
    /// the full bundle.
    fn delta_for(
        &self,
        have_generation: u64,
        bundle: &persist::StateBundle,
    ) -> Option<Vec<(String, Vec<u8>)>> {
        let (router_version, shard_versions) = {
            let hist =
                self.ship_history.lock().unwrap_or_else(|e| e.into_inner());
            let (_, rv, sv) =
                hist.iter().find(|(g, _, _)| *g == have_generation)?;
            (*rv, sv.clone())
        };
        persist::delta_files(bundle, router_version, &shard_versions)
    }

    /// Ship the durable state, cut at a checkpoint generation (the
    /// `FetchState` wire op lands here). `have_generation` makes polling
    /// cheap: when it matches the current generation the shipment
    /// carries no files. When the requester's generation is in the
    /// delta index and the router has not moved, only the shard files
    /// whose version advanced are shipped (`delta = true`); a full
    /// bundle that outgrows one frame ships as chunk 1 of N, the rest
    /// via `FetchChunk`. Served by leaders and by mirror-keeping
    /// followers (the fan-out tree); errors without durable state.
    ///
    /// When a trace is live, the consistent-cut read and the shipment
    /// assembly land as `state.cut` / `state.ship` spans under `parent`
    /// — a follower's wire context joins them into its sync-cycle trace.
    pub fn fetch_state(
        &self,
        have_generation: u64,
        mut trace: TraceSink<'_>,
        parent: u64,
    ) -> Result<StateShipment> {
        self.shippable()?;
        let dir = self.state_dir.as_ref().ok_or_else(|| {
            anyhow!(
                "state shipping needs durable state (start the leader with \
                 --state-dir)"
            )
        })?;
        let leader_version = self.version();
        // Fast path for the common poll: a requester can only hold a
        // generation that actually reached the disk, and the in-memory
        // clock only equals such a value when the disk still carries it
        // (a failed manifest save leaves the clock strictly ahead). So
        // equality here means "nothing new" without touching any file.
        if have_generation == self.state_generation.load(Ordering::Acquire) {
            return Ok(StateShipment {
                generation: have_generation,
                leader_version,
                ..StateShipment::default()
            });
        }
        let t0 = Instant::now();
        faults::hit("state.cut")?;
        let cut_span = trace.as_mut().map(|tb| tb.begin("state.cut", parent));
        let bundle = persist::read_bundle(dir)?.ok_or_else(|| {
            anyhow!("{} holds no checkpointed state yet", dir.display())
        })?;
        if let (Some(tb), Some(id)) = (trace.as_mut(), cut_span) {
            tb.end(id);
        }
        // Index this cut so the requester's NEXT poll can be a delta.
        self.remember_cut(&bundle);
        if bundle.generation == have_generation {
            return Ok(StateShipment {
                generation: bundle.generation,
                leader_version,
                ..StateShipment::default()
            });
        }
        faults::hit("state.ship")?;
        let ship_span = trace.as_mut().map(|tb| tb.begin("state.ship", parent));
        let shipment =
            self.cut_to_shipment(bundle, have_generation, leader_version, t0);
        if let (Some(tb), Some(id)) = (trace.as_mut(), ship_span) {
            tb.end(id);
        }
        Ok(shipment)
    }

    /// [`VqService::fetch_state`]'s delta index entry for `bundle`.
    fn remember_cut(&self, bundle: &persist::StateBundle) {
        self.remember_versions(
            bundle.generation,
            bundle.manifest.router_version,
            bundle.manifest.shard_versions.clone(),
        );
    }

    /// Shape a consistent cut into the wire's first shipment frame: a
    /// single-frame **delta** when the requester's generation is in the
    /// delta index and the delta fits the chunk budget; otherwise the
    /// full bundle — chunk 1 of N when it outgrows one frame.
    fn cut_to_shipment(
        &self,
        bundle: persist::StateBundle,
        have_generation: u64,
        leader_version: u64,
        t0: Instant,
    ) -> StateShipment {
        if let Some(files) = self.delta_for(have_generation, &bundle) {
            let bytes: usize = files.iter().map(|(_, b)| b.len()).sum();
            if bytes <= SHIP_CHUNK_BUDGET {
                self.telemetry.journal().info(
                    "state.ship",
                    format!(
                        "shipped generation {} as a delta over \
                         {have_generation} ({} files, {bytes} bytes) in \
                         {} ms",
                        bundle.generation,
                        files.len(),
                        t0.elapsed().as_millis()
                    ),
                );
                return StateShipment {
                    generation: bundle.generation,
                    leader_version,
                    chunk: 1,
                    chunks: 1,
                    delta: true,
                    files: whole_state_files(files),
                };
            }
        }
        let total_bytes = bundle.total_bytes();
        let parts = persist::chunk_files(&bundle.files, SHIP_CHUNK_BUDGET);
        let chunks = parts.len().max(1) as u32;
        self.telemetry.journal().info(
            "state.ship",
            format!(
                "shipped generation {} ({} files, {total_bytes} bytes, \
                 {chunks} chunks) in {} ms",
                bundle.generation,
                bundle.files.len(),
                t0.elapsed().as_millis()
            ),
        );
        StateShipment {
            generation: bundle.generation,
            leader_version,
            chunk: 1,
            chunks,
            delta: false,
            files: parts
                .into_iter()
                .next()
                .map_or(Vec::new(), part_state_files),
        }
    }

    /// One numbered chunk of a full-bundle shipment (the `FetchChunk`
    /// wire op). Deterministic: the same generation always cuts into
    /// the same parts, so a client fetches 2..=N after the first frame
    /// — and a new checkpoint generation landing mid-collection errors
    /// loudly instead of splicing two different cuts together.
    pub fn fetch_chunk(
        &self,
        generation: u64,
        chunk: u32,
        mut trace: TraceSink<'_>,
        parent: u64,
    ) -> Result<StateShipment> {
        self.shippable()?;
        let dir = self.state_dir.as_ref().ok_or_else(|| {
            anyhow!(
                "state shipping needs durable state (start the leader with \
                 --state-dir)"
            )
        })?;
        faults::hit("state.cut")?;
        let cut_span = trace.as_mut().map(|tb| tb.begin("state.cut", parent));
        let bundle = persist::read_bundle(dir)?.ok_or_else(|| {
            anyhow!("{} holds no checkpointed state yet", dir.display())
        })?;
        if let (Some(tb), Some(id)) = (trace.as_mut(), cut_span) {
            tb.end(id);
        }
        if bundle.generation != generation {
            bail!(
                "chunk fetch raced a new checkpoint generation (chunk \
                 {chunk} of generation {generation} asked, the state dir \
                 now carries {}); restart the fetch",
                bundle.generation
            );
        }
        let parts = persist::chunk_files(&bundle.files, SHIP_CHUNK_BUDGET);
        let chunks = parts.len().max(1) as u32;
        if chunk == 0 || chunk > chunks {
            bail!(
                "generation {generation} cuts into {chunks} chunks; there \
                 is no chunk {chunk}"
            );
        }
        faults::hit("state.ship")?;
        let files = parts
            .into_iter()
            .nth(chunk as usize - 1)
            .map_or(Vec::new(), part_state_files);
        Ok(StateShipment {
            generation,
            leader_version: self.version(),
            chunk,
            chunks,
            delta: false,
            files,
        })
    }

    /// The `Demote` wire op lands here: a peer claiming leadership at
    /// `generation` — strictly above ours, the fencing rule — tells
    /// this service to stand down and redirect to `new_leader`. On an
    /// old leader that returned after a failover this flips every write
    /// and state fetch into a `NotLeader` redirect; on a follower it
    /// re-points the sync source (and un-promotes a rival promotee, so
    /// a partitioned pair converges on the higher generation).
    pub fn demote(&self, generation: u64, new_leader: &str) -> Result<()> {
        let own = self.state_generation.load(Ordering::Acquire);
        if generation <= own {
            bail!(
                "refusing demotion: presented generation {generation} is \
                 not above this service's {own}"
            );
        }
        if new_leader.is_empty() {
            bail!("refusing demotion: no leader address to redirect to");
        }
        match &self.follower {
            Some(f) => {
                *f.leader_addr.lock().unwrap_or_else(|e| e.into_inner()) =
                    new_leader.to_string();
                f.promoted.store(false, Ordering::Release);
                f.patrol_done.store(false, Ordering::Release);
                f.force_full.store(true, Ordering::Release);
                f.misses.store(0, Ordering::Release);
            }
            None => {
                *self.demoted.lock().unwrap_or_else(|e| e.into_inner()) =
                    Some(new_leader.to_string());
            }
        }
        self.telemetry.journal().info(
            "failover.demote",
            format!(
                "demoted under generation {generation} (own {own}); \
                 redirecting to the leader at {new_leader}"
            ),
        );
        Ok(())
    }

    /// Automatic failover: this follower missed `misses` consecutive
    /// sync polls, crossing `--miss-threshold`. Its mirror dir is a
    /// byte-identical cut of the last adopted generation, so taking
    /// leadership is: rewrite the mirror's manifest a fencing jump
    /// ahead (any generation comparison now sees this copy as strictly
    /// newer) and stop redirecting. Reads never drop — the adopted
    /// epoch keeps serving throughout.
    fn promote(&self, misses: u64) -> Result<()> {
        let f = self
            .follower
            .as_ref()
            .ok_or_else(|| anyhow!("promote on a leader"))?;
        let dir = self.state_dir.as_ref().ok_or_else(|| {
            anyhow!("failover needs a mirror --state-dir to promote from")
        })?;
        faults::hit("promote.manifest")?;
        let bundle = persist::read_bundle(dir)?.ok_or_else(|| {
            anyhow!("{} holds no mirrored state to promote", dir.display())
        })?;
        let mut m = bundle.manifest;
        let adopted = m.generation;
        m.generation += PROMOTE_GENERATION_JUMP;
        m.save(dir)?;
        faults::hit("promote.swap")?;
        self.remember_versions(
            m.generation,
            m.router_version,
            m.shard_versions.clone(),
        );
        self.state_generation.store(m.generation, Ordering::Release);
        f.lag_folds.store(0, Ordering::Release);
        self.telemetry.gauge("sync.lag_folds").set(0);
        f.promoted.store(true, Ordering::Release);
        self.telemetry.counter("failover.promotions").add(1);
        let old = f.leader_addr.lock().unwrap_or_else(|e| e.into_inner());
        self.telemetry.journal().info(
            "failover.promote",
            format!(
                "promoted to leader at generation {} (adopted {adopted}, \
                 {misses} missed sync polls against {old})",
                m.generation
            ),
        );
        Ok(())
    }

    /// One probe of the demote patrol: a promoted leader keeps knocking
    /// on the OLD leader's address, and the moment something answers
    /// there, sends `Demote` with its own (higher) generation and
    /// advertised address. A dead address is silence (the common case);
    /// an acknowledged demote ends the patrol — the old leader now
    /// redirects its clients here.
    fn demote_patrol(&self) {
        let Some(f) = &self.follower else { return };
        let Some(me) = self
            .advertise
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
        else {
            return; // not serving over TCP; nothing to redirect to
        };
        if faults::hit("demote.patrol").is_err() {
            return; // injected partition: skip this probe
        }
        let old =
            f.leader_addr.lock().unwrap_or_else(|e| e.into_inner()).clone();
        let Ok(mut client) =
            Client::connect_with(old.as_str(), SYNC_CONNECT_TIMEOUT, 0)
        else {
            return;
        };
        let generation = self.state_generation.load(Ordering::Acquire);
        if client.demote(generation, me.as_str()).is_ok() {
            f.patrol_done.store(true, Ordering::Release);
            self.telemetry.journal().info(
                "failover.demote",
                format!(
                    "old leader {old} acknowledged demotion under \
                     generation {generation}; its clients now redirect \
                     to {me}"
                ),
            );
        }
    }

    /// Record the address this service serves on (the TCP front-end
    /// calls this once it binds) — what a promotion advertises.
    pub(crate) fn set_advertise_addr(&self, addr: String) {
        *self.advertise.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(addr);
    }

    /// The serving epoch — one consistent (router, fleets) pair. O(1)
    /// `Arc` clone, same discipline as [`SnapshotStore::load`].
    fn current(&self) -> Arc<Epoch> {
        Arc::clone(&self.epoch.lock().unwrap_or_else(|e| e.into_inner()))
    }

    /// Prototype dimension every query batch must be a multiple of.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total prototypes across shards.
    pub fn kappa(&self) -> usize {
        self.kappa
    }

    /// Shard count of the serving epoch. On a leader this is the
    /// configured `shards`; on a follower it is whatever the leader's
    /// manifest shipped (the local config's shard count is ignored).
    pub fn shards(&self) -> usize {
        self.current().shards.len()
    }

    /// Shards probed per query point (clamped to the adopted shard
    /// count on a follower).
    pub fn probe_n(&self) -> usize {
        self.probe_n
    }

    /// The current epoch's coarse quantizer (diagnostics, tests,
    /// oracles). A clone: the backing epoch may be swapped by a
    /// rebalance the moment this returns.
    pub fn router(&self) -> Router {
        self.current().router.clone()
    }

    /// Partition version of the serving epoch (0 = bootstrap router;
    /// bumped by every rebalance).
    pub fn router_version(&self) -> u64 {
        self.current().router_version
    }

    /// Release a fleet started with `start_paused` (no-op otherwise).
    pub fn resume(&self) {
        self.current().go.store(true, Ordering::Release);
    }

    /// Current published epoch of one shard.
    pub fn shard_snapshot(&self, s: usize) -> Arc<Snapshot> {
        self.current().shards[s].store.load()
    }

    /// Current epochs of every shard, in shard order.
    pub fn snapshots(&self) -> Vec<Arc<Snapshot>> {
        self.current().shards.iter().map(|s| s.store.load()).collect()
    }

    /// A coherent global view: with one shard, the shard's epoch as-is
    /// (O(1) `Arc` clone); with several, a freshly assembled snapshot
    /// whose codebook concatenates the shard codebooks in shard order
    /// (rows match the global codes queries return) and whose version is
    /// the per-shard sum.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        let ep = self.current();
        if ep.shards.len() == 1 {
            return ep.shards[0].store.load();
        }
        let snaps: Vec<Arc<Snapshot>> =
            ep.shards.iter().map(|s| s.store.load()).collect();
        let mut flat = Vec::with_capacity(self.kappa * self.dim);
        let mut version = 0u64;
        for snap in &snaps {
            flat.extend_from_slice(snap.codebook.flat());
            version += snap.version;
        }
        Arc::new(Snapshot {
            codebook: Codebook::from_flat(self.kappa, self.dim, flat),
            version,
        })
    }

    /// Sum of per-shard versions (freshness polling; monotone across
    /// rebalances because migrated fleets resume at the max of the old
    /// versions).
    pub fn version(&self) -> u64 {
        self.current().shards.iter().map(|s| s.store.version()).sum()
    }

    /// Per-shard published versions, in shard order.
    pub fn shard_versions(&self) -> Vec<u64> {
        self.current().shards.iter().map(|s| s.store.version()).collect()
    }

    /// The live service-lifetime counters (shared with the front-end,
    /// which maintains `queries`).
    pub fn counters(&self) -> &Arc<ServeCounters> {
        &self.counters
    }

    /// The telemetry plane (tests and diagnostics; the wire reads it
    /// through [`VqService::metrics_snapshot`]).
    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    /// The front-end's pre-resolved hot-path metric handles.
    pub(crate) fn tel(&self) -> &ServeTel {
        &self.tel
    }

    /// Slow-query threshold in µs (0 = the log is off).
    pub(crate) fn slow_query_us(&self) -> u64 {
        self.serve.slow_query_us
    }

    /// Micro-batch coalescing window in µs (0 = the batcher is off and
    /// every read request scans immediately).
    pub(crate) fn batch_window_us(&self) -> u64 {
        self.serve.batch_window_us
    }

    /// Point budget of one coalesced micro-batch: a batch drains as soon
    /// as it holds this many points, even before the window closes.
    pub(crate) fn batch_max_points(&self) -> usize {
        self.serve.batch_max_points
    }

    /// Event-loop worker threads (0 = size to available cores).
    pub(crate) fn io_workers(&self) -> usize {
        self.serve.io_workers
    }

    /// Per-connection in-flight request cap (0 = unlimited).
    pub(crate) fn max_inflight(&self) -> usize {
        self.serve.max_inflight
    }

    /// Per-connection request rate quota, requests/s (0 = unlimited).
    pub(crate) fn rate_limit(&self) -> u64 {
        self.serve.rate_limit
    }

    /// Brownout watermark on shard ingest-queue depth (0 = brownout off).
    pub(crate) fn brownout_depth(&self) -> u64 {
        self.serve.brownout_depth
    }

    /// The deepest `shard.<s>.queue_depth` gauge of the serving epoch —
    /// the overload signal the brownout ladder watches. Reads the live
    /// gauges directly (no registry lookup; the epoch holds the handles).
    pub(crate) fn max_queue_depth(&self) -> u64 {
        self.current()
            .shards
            .iter()
            .map(|s| s.queue_depth.get())
            .max()
            .unwrap_or(0)
    }

    /// The `Metrics` wire op and the `--metrics-file` writer land here:
    /// refresh the lazily-maintained gauges — per-shard load counters and
    /// follower lag, which are kept as plain atomics on their hot paths —
    /// from the serving epoch, then cut a snapshot carrying the newest
    /// `max_events` journal entries.
    pub fn metrics_snapshot(&self, max_events: usize) -> TelemetrySnapshot {
        let ep = self.current();
        for (s, fleet) in ep.shards.iter().enumerate() {
            self.telemetry
                .gauge(&format!("shard.{s}.ingested_points"))
                .set(fleet.ingested.load(Ordering::Relaxed));
            self.telemetry
                .gauge(&format!("shard.{s}.shed_points"))
                .set(fleet.shed.load(Ordering::Relaxed));
        }
        if let Some(f) = &self.follower {
            self.telemetry
                .gauge("sync.lag_folds")
                .set(f.lag_folds.load(Ordering::Acquire));
        }
        self.telemetry.snapshot(max_events)
    }

    /// The durable state directory, when persistence is on.
    pub fn state_dir(&self) -> Option<&Path> {
        self.state_dir.as_deref()
    }

    /// Last checkpointed version per shard (empty without persistence).
    pub fn last_checkpoint(&self) -> Vec<u64> {
        if self.state_dir.is_none() {
            return Vec::new();
        }
        self.last_checkpoint
            .iter()
            .map(|v| v.load(Ordering::Acquire))
            .collect()
    }

    /// Force a checkpoint of every shard that advanced since its last
    /// one; blocks until the files are durable. Returns the per-shard
    /// checkpointed versions (the protocol's `Checkpoint` op lands here).
    pub fn checkpoint_now(&self) -> Result<Vec<u64>> {
        if let Some(f) = &self.follower {
            if f.promoted.load(Ordering::Acquire) {
                return Err(anyhow!(
                    "this server was promoted from a follower; its mirror \
                     dir already carries the adopted state (restart it as \
                     a leader to resume checkpointing)"
                ));
            }
            return Err(anyhow!(
                "this server is a read-only follower; checkpoints belong on \
                 the leader at {}",
                f.leader_addr.lock().unwrap_or_else(|e| e.into_inner())
            ));
        }
        if self.state_dir.is_none() {
            return Err(anyhow!(
                "service has no durable state (started without --state-dir)"
            ));
        }
        let guard = self.checkpointer.lock().unwrap_or_else(|e| e.into_inner());
        match guard.as_ref() {
            Some(ck) => ck.flush(),
            // With a state dir, an empty slot only ever means a rebalance
            // holds the checkpointer between retiring the old epoch's and
            // spawning the new one's.
            None => Err(anyhow!(
                "a rebalance is migrating the shards; retry the checkpoint \
                 once the epoch swap completes"
            )),
        }
    }

    // ---------------------------------------------------------- rebalance

    /// Re-partition the service online: quiesce the current epoch's
    /// fleets, flush their state to the durable directory, retrain the
    /// coarse quantizer from the checkpointed codebooks (rows weighted by
    /// the per-shard ingest observed this epoch), migrate prototype rows
    /// across the shard files, restart fresh fleets from the rewritten
    /// directory, and swap the new epoch in.
    ///
    /// The read path never drops: queries keep answering from the old
    /// epoch's final published snapshots until the swap. Ingest routed to
    /// the draining epoch is shed (at-most-once transport, same contract
    /// as a full queue). Requires durable state — the checkpointed files,
    /// not any live fleet, are the migration source.
    pub fn rebalance(&self) -> Result<RebalanceOutcome> {
        if let Some(f) = &self.follower {
            if f.promoted.load(Ordering::Acquire) {
                bail!(
                    "this server was promoted from a follower and has no \
                     training fleets to migrate; restart it as a leader on \
                     its mirror --state-dir first"
                );
            }
            bail!(
                "this server is a read-only follower; rebalances belong on \
                 the leader at {} (the bumped epoch replicates here on the \
                 next sync)",
                f.leader_addr.lock().unwrap_or_else(|e| e.into_inner())
            );
        }
        let _lifecycle = self.lifecycle.lock().unwrap_or_else(|e| e.into_inner());
        if self.closing.load(Ordering::Acquire) {
            bail!("service is shutting down");
        }
        let dir = self.state_dir.clone().ok_or_else(|| {
            anyhow!(
                "rebalance needs durable state (start with --state-dir): \
                 the checkpointed shard files are the migration source"
            )
        })?;

        // 1. Quiesce the serving fleets. Their stores keep answering
        //    queries from the final published snapshots. Taking the
        //    handles is the only "already shut down" source and mutates
        //    nothing; once we own them, ANY later failure must revive —
        //    never leave the service quiesced and write-dead.
        let t_quiesce = Instant::now();
        let old = self.current();
        let fleets = take_fleets(&old)?;
        if let Err(e) = join_fleets(&old, fleets) {
            self.telemetry.journal().error(
                "rebalance.quiesce",
                format!(
                    "quiesce failed after {} ms: {e:#}",
                    t_quiesce.elapsed().as_millis()
                ),
            );
            self.revive_previous(&dir, &old)?;
            return Err(e.context(
                "quiescing for a rebalance failed; the previous partition \
                 was revived and keeps serving",
            ));
        }
        self.telemetry.journal().info(
            "rebalance.quiesce",
            format!(
                "quiesced {} shard fleets in {} ms",
                old.shards.len(),
                t_quiesce.elapsed().as_millis()
            ),
        );
        let old_version_sum: u64 =
            old.shards.iter().map(|f| f.store.version()).sum();

        // 2-4. Retire this epoch's checkpointer (its final drain persists
        //    exactly the post-quiesce state — codebooks, fold clocks,
        //    ingest counters — the migration will read), migrate the
        //    durable state offline, then restart fleets from the
        //    rewritten directory — the same warm path a killed-and-
        //    restarted process takes, so what serves after the swap IS
        //    what a restart would serve. Everything fallible from here on
        //    runs inside one closure so ANY failure — including the flush
        //    — takes the revive path below instead of leaving the service
        //    quiesced.
        let t_migrate = Instant::now();
        let migrated = (|| -> Result<(persist::RebalanceReport, RestoredState, Epoch)> {
            match self
                .checkpointer
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
            {
                Some(ck) => {
                    ck.stop().context("flushing pre-rebalance state")?
                }
                None => {
                    bail!("rebalance lost the checkpointer (double shutdown?)")
                }
            }
            let report = persist::rebalance_state_dir(
                &dir,
                self.serve.router_iters,
                self.cfg.seed,
            )?;
            let restored =
                load_restore(&dir, &self.cfg, &self.serve)?.ok_or_else(|| {
                    anyhow!("state dir lost its manifest mid-rebalance")
                })?;
            let router =
                Router::from_centroids(restored.router.centroids.clone());
            let seeds = seeds_from_restored(&restored, &self.serve, self.cfg.m);
            let epoch = spawn_epoch(
                &self.cfg,
                &self.serve,
                &self.counters,
                &self.telemetry,
                router,
                restored.manifest.router_version,
                Some(seeds),
                false, // migrated fleets start live, never paused
            )?;
            Ok((report, restored, epoch))
        })();
        let (report, restored, epoch) = match migrated {
            Ok(ok) => ok,
            // A failed migration (disk full mid-write, torn directory)
            // must not brick the service: the old fleets are already
            // quiesced, so revive the PREVIOUS partition from its
            // in-memory final snapshots, heal the possibly-torn state dir
            // back to it, and only then surface the error — writes keep
            // flowing and a later retry (or the monitor) can attempt the
            // migration again.
            Err(e) => {
                self.telemetry.journal().error(
                    "rebalance.migrate",
                    format!(
                        "migration failed after {} ms; reviving the \
                         previous partition: {e:#}",
                        t_migrate.elapsed().as_millis()
                    ),
                );
                self.revive_previous(&dir, &old)?;
                return Err(e.context(
                    "rebalance failed; the previous partition was revived \
                     and keeps serving",
                ));
            }
        };
        self.telemetry.journal().info(
            "rebalance.migrate",
            format!(
                "retrained router to v{} and moved {} rows in {} ms",
                report.router_version,
                report.moved_rows,
                t_migrate.elapsed().as_millis()
            ),
        );

        // 5. Publish: swap the epoch, re-seed the checkpoint bookkeeping,
        //    spawn the new epoch's checkpointer, advance the fold clock
        //    past the version jump (migrated fleets resume at max of the
        //    old versions, so the summed version stays monotone and
        //    `merges >= version` keeps holding).
        let t_swap = Instant::now();
        let shard_versions: Vec<u64> =
            restored.shards.iter().map(|s| s.version).collect();
        let new_version_sum: u64 = shard_versions.iter().sum();
        self.counters.merges.fetch_add(
            new_version_sum.saturating_sub(old_version_sum),
            Ordering::Relaxed,
        );
        // The offline migration bumped the manifest's generation on
        // disk; re-seed the shared clock so the new epoch's checkpointer
        // continues the sequence and pollers see the migration.
        self.state_generation
            .store(restored.manifest.generation, Ordering::Release);
        self.publish_epoch(&dir, epoch);
        self.counters.rebalances.fetch_add(1, Ordering::Relaxed);
        self.telemetry.journal().info(
            "rebalance.swap",
            format!(
                "published router v{} ({} shards) in {} ms",
                report.router_version,
                shard_versions.len(),
                t_swap.elapsed().as_millis()
            ),
        );
        Ok(RebalanceOutcome {
            router_version: report.router_version,
            moved_rows: report.moved_rows as u64,
            shard_versions,
            remap: report.remap,
        })
    }

    /// Rebuild and publish the previous partition from a quiesced epoch's
    /// in-memory final snapshots — the rebalance failure path. Retires a
    /// still-running checkpointer first (two writers on one state dir is
    /// never allowed), best-effort-heals the directory back to the old
    /// partition, and swaps the revived epoch in. The heal is best effort
    /// on purpose: the revived fleets are valid in memory regardless of
    /// the disk, and erroring between spawn and publish would leak them
    /// running with no epoch owning them. A dir left torn is caught
    /// loudly by restore's partition-version cross-checks on the next
    /// start, and the fresh checkpointer keeps retrying shard/manifest
    /// writes on its periodic pass.
    fn revive_previous(&self, dir: &Path, old: &Epoch) -> Result<()> {
        self.telemetry.journal().warn(
            "rebalance.revive",
            format!(
                "reviving the previous partition (router v{}, {} shards) \
                 after a failed rebalance",
                old.router_version,
                old.shards.len()
            ),
        );
        if let Some(ck) = self
            .checkpointer
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            if let Err(e) = ck.stop() {
                eprintln!(
                    "dalvq rebalance: retiring the checkpointer during \
                     revival failed (its last write may be stale): {e:#}"
                );
            }
        }
        let seeds = seeds_from_epoch(old, &self.serve, self.cfg.m);
        let epoch = spawn_epoch(
            &self.cfg,
            &self.serve,
            &self.counters,
            &self.telemetry,
            old.router.clone(),
            old.router_version,
            Some(seeds),
            false,
        )
        .context("reviving the previous partition after a failed rebalance")?;
        // The heal rewrites the directory, so it is a generation bump
        // like any other write — and it must advance past anything a
        // poller could already have fetched. The aborted migration may
        // have published its bumped generation on disk (the migrated
        // manifest lands before the failure), which the in-memory clock
        // has not seen; healing at that same number would make a
        // follower that adopted the migrated bundle believe it is
        // current and keep serving the rolled-back partition forever.
        let disk_generation = Manifest::load(dir)
            .ok()
            .flatten()
            .map_or(0, |m| m.generation);
        let generation = disk_generation
            .max(self.state_generation.load(Ordering::Acquire))
            + 1;
        self.state_generation.store(generation, Ordering::Release);
        if let Err(heal) =
            write_initial_state(dir, &epoch, &self.cfg, &self.serve, generation)
        {
            eprintln!(
                "dalvq rebalance: healing the state dir back to the \
                 previous partition failed (dir stays torn until the next \
                 successful checkpoint or rebalance): {heal:#}"
            );
        }
        self.publish_epoch(dir, epoch);
        Ok(())
    }

    /// Install `epoch` as the serving partition: sync the last-checkpoint
    /// bookkeeping to its shard versions (they equal what its files on
    /// disk carry — both the migrated and the revived path write the
    /// directory before publishing), hand its stores to a fresh
    /// checkpointer, and swap the epoch cell.
    fn publish_epoch(&self, dir: &Path, epoch: Epoch) {
        for (s, fleet) in epoch.shards.iter().enumerate() {
            self.last_checkpoint[s]
                .store(fleet.store.version(), Ordering::Release);
        }
        let checkpointer = spawn_checkpointer(
            dir,
            &epoch,
            &self.last_checkpoint,
            &self.state_generation,
            &self.telemetry,
            &self.cfg,
            &self.serve,
        );
        *self.epoch.lock().unwrap_or_else(|e| e.into_inner()) = Arc::new(epoch);
        *self.checkpointer.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(checkpointer);
    }

    // -------------------------------------------------------- query path

    /// Quantize: global nearest-prototype code per point, via multi-probe
    /// over the configured `probe_n` shards. Returns the aggregate version
    /// that answered. Global code = `shard * kappa/S + local index`
    /// within the current router epoch.
    pub fn query_encode(&self, points: &[f32]) -> (u64, Vec<u32>) {
        let (version, codes, _) = self.query_nearest_probed(points, self.probe_n);
        (version, codes)
    }

    /// Nearest prototype per point with squared distances, at the
    /// configured probe width.
    pub fn query_nearest(&self, points: &[f32]) -> (u64, Vec<u32>, Vec<f32>) {
        self.query_nearest_probed(points, self.probe_n)
    }

    /// Nearest prototype per point, probing the `probe_n` closest shards
    /// (clamped to `1..=S`). `probe_n = S` is the exhaustive oracle the
    /// drift suite compares routed answers against. Routing and shard
    /// snapshots resolve against ONE epoch (`Arc`-cloned up front), so a
    /// concurrent rebalance can never mix the old partition's codes with
    /// the new partition's codebooks. The scan itself is shard-grouped
    /// and fused (see [`VqService::scan_probed`]) but bit-identical to
    /// probing one point at a time.
    pub fn query_nearest_probed(
        &self,
        points: &[f32],
        probe_n: usize,
    ) -> (u64, Vec<u32>, Vec<f32>) {
        let q = self.query_probed_inner(points, probe_n);
        (q.version, q.codes, q.dists)
    }

    /// [`VqService::query_nearest_probed`] with per-stage timings — the
    /// front-end's instrumented entry point. Identical answers (both
    /// paths share [`VqService::query_probed_inner`]); this one also
    /// records the stage timings into the telemetry plane and returns
    /// their µs for the slow-query log.
    pub(crate) fn query_nearest_timed(
        &self,
        points: &[f32],
        probe_n: usize,
    ) -> TimedQuery {
        let q = self.query_probed_inner(points, probe_n);
        self.tel.route_us.record(q.route_us);
        self.tel.scan_us.record(q.scan_us);
        q
    }

    /// The shared read path. Stage 1 routes every point through the
    /// coarse quantizer, collecting flat probe lists so the scan never
    /// re-routes; stage 2 is the shard-grouped fused scan. Records
    /// nothing — the timed wrapper owns telemetry, so an untimed call
    /// leaves the histograms untouched.
    fn query_probed_inner(&self, points: &[f32], probe_n: usize) -> TimedQuery {
        assert_eq!(points.len() % self.dim, 0, "points not a multiple of dim");
        let ep = self.current();
        let snaps: Vec<Arc<Snapshot>> =
            ep.shards.iter().map(|s| s.store.load()).collect();
        let version = snaps.iter().map(|s| s.version).sum();
        let n = points.len() / self.dim;

        let t_route = Instant::now();
        let mut flat_probes = Vec::with_capacity(n * probe_n);
        let mut probe_lens = Vec::with_capacity(n);
        let mut probes = Vec::with_capacity(probe_n);
        for z in points.chunks_exact(self.dim) {
            ep.router.probe_into(z, probe_n, &mut probes);
            probe_lens.push(probes.len());
            flat_probes.extend_from_slice(&probes);
        }
        let route_us = t_route.elapsed().as_micros() as u64;

        let t_scan = Instant::now();
        let (codes, dists) =
            self.scan_probed(&snaps, points, &flat_probes, &probe_lens);
        let scan_us = t_scan.elapsed().as_micros() as u64;
        TimedQuery { version, codes, dists, route_us, scan_us }
    }

    /// The fused scan stage: instead of `n · probe_n` scalar codebook
    /// sweeps, gather each shard's (point, probe) pairs into one
    /// contiguous query block, run ONE [`crate::vq::nearest_batch`] pass
    /// per probed shard, scatter the per-pair results into a flat buffer,
    /// then merge each point's pairs **in probe order** with the same
    /// strict-`<` rule as the scalar loop. Per pair the kernel is
    /// bit-identical to `Snapshot::nearest_one` (same row order, same
    /// four-lane distance sum) and the merge visits pairs in the same
    /// order with the same comparison, so the answers are bit-identical
    /// to the pre-batching path — the `query_plane` suite pins this
    /// against a scalar oracle over random shapes.
    fn scan_probed(
        &self,
        snaps: &[Arc<Snapshot>],
        points: &[f32],
        flat_probes: &[usize],
        probe_lens: &[usize],
    ) -> (Vec<u32>, Vec<f32>) {
        // Gather: one contiguous point block per shard, plus the pair
        // slot each gathered point's result scatters back into.
        let mut shard_points: Vec<Vec<f32>> = vec![Vec::new(); snaps.len()];
        let mut shard_slots: Vec<Vec<usize>> = vec![Vec::new(); snaps.len()];
        let mut off = 0usize;
        for (z, &len) in points.chunks_exact(self.dim).zip(probe_lens) {
            for (slot, &s) in (off..off + len).zip(&flat_probes[off..off + len]) {
                shard_points[s].extend_from_slice(z);
                shard_slots[s].push(slot);
            }
            off += len;
        }

        // One fused codebook sweep per shard.
        let mut pair_codes = vec![0u32; flat_probes.len()];
        let mut pair_dists = vec![0.0f32; flat_probes.len()];
        let mut codes_buf: Vec<u32> = Vec::new();
        let mut dists_buf: Vec<f32> = Vec::new();
        for (s, snap) in snaps.iter().enumerate() {
            let slots = &shard_slots[s];
            if slots.is_empty() {
                continue;
            }
            codes_buf.resize(slots.len(), 0);
            dists_buf.resize(slots.len(), 0.0);
            nearest_batch_into(
                &snap.codebook,
                &shard_points[s],
                &mut codes_buf,
                &mut dists_buf,
            );
            for (i, &slot) in slots.iter().enumerate() {
                pair_codes[slot] = codes_buf[i];
                pair_dists[slot] = dists_buf[i];
            }
        }

        // Merge per point, walking its pairs in probe order (strict `<`:
        // ties keep the earlier probe, exactly like the scalar loop).
        let mut codes = Vec::with_capacity(probe_lens.len());
        let mut dists = Vec::with_capacity(probe_lens.len());
        let mut off = 0usize;
        for &len in probe_lens {
            let mut best_code = 0u32;
            let mut best_d = f32::INFINITY;
            for j in off..off + len {
                let d = pair_dists[j];
                if d < best_d {
                    best_d = d;
                    best_code =
                        (flat_probes[j] * self.kappa_shard) as u32 + pair_codes[j];
                }
            }
            off += len;
            codes.push(best_code);
            dists.push(best_d);
        }
        (codes, dists)
    }

    /// Normalized empirical distortion of `points` (paper eq. 2) under the
    /// sharded codebook, at the configured probe width. Empty input is a
    /// defined 0.0.
    pub fn query_distortion(&self, points: &[f32]) -> (u64, f64) {
        let (version, _codes, dists) = self.query_nearest_probed(points, self.probe_n);
        if dists.is_empty() {
            return (version, 0.0);
        }
        let sum: f64 = dists.iter().map(|d| *d as f64).sum();
        (version, sum / dists.len() as f64)
    }

    // ------------------------------------------------------- ingest path

    /// Feed points into the training stream. Each point is routed to the
    /// shard owning its coarse cell in the current epoch, then sharded
    /// round-robin across that fleet's workers; a full worker queue sheds
    /// its sub-batch (at-most-once ingestion — the stochastic algorithm
    /// tolerates loss, and blocking here would couple ingest pressure to
    /// query latency). A batch routed to an epoch that is draining for a
    /// rebalance is shed the same way. Returns `(accepted, shed)` point
    /// counts.
    pub fn ingest(&self, points: &[f32]) -> Result<(u64, u64)> {
        if let Some(f) = &self.follower {
            if f.promoted.load(Ordering::Acquire) {
                return Err(anyhow!(
                    "this server was promoted from a follower and serves \
                     reads only; restart it as a leader on its mirror \
                     --state-dir to resume training"
                ));
            }
            return Err(anyhow!(
                "this server is a read-only follower; ingest belongs on the \
                 leader at {}",
                f.leader_addr.lock().unwrap_or_else(|e| e.into_inner())
            ));
        }
        if points.is_empty() {
            return Ok((0, 0));
        }
        if points.len() % self.dim != 0 {
            return Err(anyhow!(
                "ingest batch of {} floats is not a multiple of dim {}",
                points.len(),
                self.dim
            ));
        }
        let ep = self.current();
        // Resolve every destination before sending anything: the reply
        // must stay all-or-nothing with respect to shutdown — it may never
        // claim points were accepted on one shard and then error on the
        // next (the pre-sharding path had exactly one send, so this was
        // free; with a fan-out it has to be a two-phase walk).
        let mut sends = Vec::new();
        let mut drained = Vec::new();
        for (s, part) in ep.router.partition(points).into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            let shard = &ep.shards[s];
            let tx = {
                let txs = shard.ingest_txs.lock().unwrap_or_else(|e| e.into_inner());
                if txs.is_empty() {
                    // This epoch's fleets are gone: a hard error while the
                    // service closes, a shed while it migrates.
                    if self.closing.load(Ordering::Acquire) {
                        return Err(anyhow!("service is shutting down"));
                    }
                    drained.push((s, (part.len() / self.dim) as u64));
                    continue;
                }
                let i = shard.ingest_cursor.fetch_add(1, Ordering::Relaxed) % txs.len();
                txs[i].clone()
            };
            sends.push((s, part, tx));
        }
        let mut accepted = 0u64;
        let mut shed = 0u64;
        for (s, n) in drained {
            self.counters.ingest_shed.fetch_add(n, Ordering::Relaxed);
            ep.shards[s].shed.fetch_add(n, Ordering::Relaxed);
            shed += n;
        }
        for (s, part, tx) in sends {
            let n = (part.len() / self.dim) as u64;
            match tx.try_send(part) {
                Ok(()) => {
                    self.counters.ingested.fetch_add(n, Ordering::Relaxed);
                    ep.shards[s].ingested.fetch_add(n, Ordering::Relaxed);
                    // One batch now sits unabsorbed in a worker's queue;
                    // the worker decrements when it takes it off.
                    ep.shards[s].queue_depth.add(1);
                    accepted += n;
                }
                // Full queue — or a worker that raced us into shutdown and
                // hung up — both shed: at-most-once transport, and the
                // tally the client sees stays consistent with the
                // counters.
                Err(mpsc::TrySendError::Full(_))
                | Err(mpsc::TrySendError::Disconnected(_)) => {
                    self.counters.ingest_shed.fetch_add(n, Ordering::Relaxed);
                    ep.shards[s].shed.fetch_add(n, Ordering::Relaxed);
                    shed += n;
                }
            }
        }
        Ok((accepted, shed))
    }

    /// Counters + shape, for the `Stats` query.
    pub fn stats(&self) -> ServeStats {
        let ep = self.current();
        ServeStats {
            version: ep.shards.iter().map(|s| s.store.version()).sum(),
            kappa: self.kappa,
            dim: self.dim,
            workers: self.workers_per_shard * ep.shards.len(),
            shards: ep.shards.len(),
            probe_n: self.probe_n,
            router_version: ep.router_version,
            rebalances: self.counters.rebalances.load(Ordering::Relaxed),
            merges: self.counters.merges.load(Ordering::Relaxed),
            ingested: self.counters.ingested.load(Ordering::Relaxed),
            ingest_shed: self.counters.ingest_shed.load(Ordering::Relaxed),
            queries: self.counters.queries.load(Ordering::Relaxed),
            shard_versions: ep.shards.iter().map(|s| s.store.version()).collect(),
            shard_merges: ep
                .shards
                .iter()
                .map(|s| s.merges.load(Ordering::Relaxed))
                .collect(),
            shard_ingest: ep
                .shards
                .iter()
                .map(|s| s.ingested.load(Ordering::Relaxed))
                .collect(),
            shard_shed: ep
                .shards
                .iter()
                .map(|s| s.shed.load(Ordering::Relaxed))
                .collect(),
            state_dir: self
                .state_dir
                .as_ref()
                .map(|d| d.display().to_string()),
            last_checkpoint: self.last_checkpoint(),
            // A promoted follower reports (and serves) as a leader; a
            // demoted leader as a follower of whoever fenced it.
            role: match self.follower_of() {
                Some(_) => "follower".into(),
                None => "leader".into(),
            },
            leader_addr: self.follower_of(),
            sync_lag_folds: self
                .follower
                .as_ref()
                .map_or(0, |f| f.lag_folds.load(Ordering::Acquire)),
            last_sync_ms: self.follower.as_ref().map_or(0, |f| {
                f.last_sync
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .elapsed()
                    .as_millis() as u64
            }),
            sync_source: self.follower.as_ref().map_or_else(String::new, |f| {
                f.sync_source.lock().unwrap_or_else(|e| e.into_inner()).clone()
            }),
            uptime_ms: self.telemetry.uptime_ms(),
            op_encode: self.tel.op_encode.requests.get(),
            op_nearest: self.tel.op_nearest.requests.get(),
            op_distortion: self.tel.op_distortion.requests.get(),
            op_ingest: self.tel.op_ingest.requests.get(),
        }
    }

    /// Stop the service: join the skew monitor, quiesce the current
    /// epoch's fleets (flag the workers, let them drain and flush, close
    /// the queues, join the reducers), drain the checkpointer. Each
    /// shard's final shared version is published before return, so a
    /// post-shutdown `snapshot()` is complete.
    ///
    /// Takes `&self` so the service can stay shared with open connections;
    /// those keep answering queries from the last epochs. Calling it twice
    /// is an error.
    pub fn shutdown(&self) -> Result<ServeOutcome> {
        self.closing.store(true, Ordering::Release);
        // The metrics-file writer exits on `closing`; join it first so
        // its final snapshot is on disk before the fleets quiesce.
        if let Some(j) = self
            .metrics_writer
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            let _ = j.join();
        }
        // Follower: there are no fleets or checkpointer to drain — join
        // the sync loop and report the final adopted epoch. The read
        // path stays up afterwards, same as a quiesced leader.
        if let Some(f) = &self.follower {
            let handle = f
                .thread
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
                .ok_or_else(|| anyhow!("service already shut down"))?;
            let _ = handle.join();
            let ep = self.current();
            let mut global_flat = Vec::with_capacity(self.kappa * self.dim);
            let mut merges = 0u64;
            let mut shards = Vec::with_capacity(ep.shards.len());
            for (s, fleet) in ep.shards.iter().enumerate() {
                let snap = fleet.store.load();
                merges += snap.version;
                global_flat.extend_from_slice(snap.codebook.flat());
                shards.push(ShardOutcome {
                    shard: s,
                    merges: snap.version,
                    final_shared: snap.codebook.clone(),
                });
            }
            return Ok(ServeOutcome {
                workers: Vec::new(),
                merges,
                final_shared: Codebook::from_flat(
                    self.kappa,
                    self.dim,
                    global_flat,
                ),
                shards,
            });
        }
        // The monitor exits on `closing`; if it is mid-rebalance, the
        // lifecycle lock below also serializes us behind it.
        if let Some(j) = self
            .monitor
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            let _ = j.join();
        }
        let _lifecycle = self.lifecycle.lock().unwrap_or_else(|e| e.into_inner());
        let ep = self.current();
        let fleets = take_fleets(&ep)?;
        let (workers, shard_outcomes) = join_fleets(&ep, fleets)?;
        // Fleets quiesced and final epochs published: drain the
        // checkpointer so the state dir carries everything that was
        // learned (its final pass sees the post-join versions).
        if let Some(ck) = self
            .checkpointer
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            ck.stop()?;
        }
        let mut total_merges = 0u64;
        let mut global_flat = Vec::with_capacity(self.kappa * self.dim);
        for outcome in &shard_outcomes {
            total_merges += outcome.merges;
            global_flat.extend_from_slice(outcome.final_shared.flat());
        }
        Ok(ServeOutcome {
            workers,
            merges: total_merges,
            final_shared: Codebook::from_flat(self.kappa, self.dim, global_flat),
            shards: shard_outcomes,
        })
    }
}

/// Phase 1 of quiescing an epoch: take ownership of every fleet handle.
/// This is the ONLY step that can fail with "already shut down" (a prior
/// quiesce took them) — it mutates nothing until every handle is secured,
/// so a failure here leaves the epoch exactly as it was.
fn take_fleets(ep: &Epoch) -> Result<Vec<(usize, Fleet)>> {
    let mut fleets = Vec::with_capacity(ep.shards.len());
    for (s, shard) in ep.shards.iter().enumerate() {
        let fleet = shard
            .fleet
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .ok_or_else(|| anyhow!("service already shut down"))?;
        fleets.push((s, fleet));
    }
    Ok(fleets)
}

/// Phase 2: stop and join the taken fleets — flag the workers, clear the
/// ingest channels so drains see closed senders, join workers, drop the
/// queue templates so the reducers drain, join the reducers. Each shard's
/// final shared version is published before this returns. The epoch's
/// stores stay valid — the read path keeps serving the final snapshots.
/// On a worker/reducer error the remaining handles are dropped: their
/// threads still exit on the stop flag (workers) or queue closure
/// (reducers), just unobserved.
fn join_fleets(
    ep: &Epoch,
    fleets: Vec<(usize, Fleet)>,
) -> Result<(Vec<ServeWorkerOutcome>, Vec<ShardOutcome>)> {
    ep.stop.store(true, Ordering::Release);
    ep.go.store(true, Ordering::Release); // release any paused workers
    // Disconnect ingest so worker drains see closed channels.
    for shard in &ep.shards {
        shard.ingest_txs.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }
    let mut workers = Vec::new();
    let mut shard_outcomes = Vec::with_capacity(fleets.len());
    for (s, fleet) in fleets {
        for j in fleet.workers {
            workers.push(j.join().map_err(|_| anyhow!("serve worker panicked"))??);
        }
        // Shard workers done: drop the template handle so its reducer
        // drains (worker-held clones are gone once the joins return).
        drop(fleet.queue_template);
        let (merges, final_shared) = fleet
            .reducer
            .join()
            .map_err(|_| anyhow!("serve reducer panicked"))??;
        shard_outcomes.push(ShardOutcome { shard: s, merges, final_shared });
    }
    Ok((workers, shard_outcomes))
}

/// Build one router epoch: partition the bootstrap dataset with the
/// epoch's router, seed and spawn every shard fleet (from `seeds` when
/// warm-starting or migrating, from a fresh init on a cold start), and
/// block until all `S * M` workers passed the ready barrier.
#[allow(clippy::too_many_arguments)]
fn spawn_epoch(
    cfg: &ExperimentConfig,
    serve: &ServeConfig,
    counters: &Arc<ServeCounters>,
    telemetry: &Arc<Telemetry>,
    router: Router,
    router_version: u64,
    seeds: Option<Vec<ShardSeed>>,
    paused: bool,
) -> Result<Epoch> {
    let dim = cfg.dim();
    let s_count = serve.shards;
    let kappa_shard = cfg.vq.kappa / s_count;
    let dataset = cfg.data.mixture.dataset(cfg.data.n_total, cfg.seed);
    let parts = router.partition(dataset.flat());

    let stop = Arc::new(AtomicBool::new(false));
    let go = Arc::new(AtomicBool::new(!paused));
    let ready = Arc::new(Barrier::new(s_count * cfg.m + 1));

    let mut shards = Vec::with_capacity(s_count);
    let mut base_versions = Vec::with_capacity(s_count);
    for (s, part) in parts.into_iter().enumerate() {
        // A shard's region must be able to seed kappa/S prototypes and
        // feed M workers; a starved cell (rare — the router's k-means
        // balances cells against the observed sample) is padded
        // cyclically.
        let min_pts = cfg.m.max(kappa_shard);
        let part = ensure_min_points(part, dim, min_pts, dataset.flat());
        let shard_data = Dataset::new(part, dim);
        // Seed state: the checkpoint on a warm start or migration
        // (codebook, version, schedule cursor, epoch load counters), a
        // fresh init on a cold one.
        let seed = match &seeds {
            Some(seeds) => {
                let st = &seeds[s];
                ShardSeed {
                    w0: st.w0.clone(),
                    version: st.version,
                    t0: st.t0,
                    ingested: st.ingested,
                    shed: st.shed,
                }
            }
            None => ShardSeed {
                w0: init_codebook(
                    cfg.vq.init,
                    kappa_shard,
                    dim,
                    shard_data.flat(),
                    // Distinct init stream per shard; shard 0 keeps
                    // the plain seed so `shards = 1` reproduces the
                    // original deployment.
                    cfg.seed ^ ((s as u64) << 17),
                ),
                version: 0,
                t0: 0,
                ingested: 0,
                shed: 0,
            },
        };
        base_versions.push(seed.version);

        let store = SnapshotStore::with_version(seed.w0.clone(), seed.version);
        let merges = Arc::new(AtomicU64::new(seed.version));
        // The gauge outlives epochs (names are stable across swaps); a
        // fresh epoch's queues start empty, so reset it.
        let queue_depth = telemetry.gauge(&format!("shard.{s}.queue_depth"));
        queue_depth.set(0);
        let blob = BlobService::spawn(seed.w0.clone());
        let (queue, queue_rx) = QueueService::create(1024);

        let reducer = {
            let blob = blob.clone();
            let store = Arc::clone(&store);
            let counters = Arc::clone(counters);
            let shard_merges = Arc::clone(&merges);
            let w0 = seed.w0.clone();
            let publish_every = serve.publish_every;
            let merges0 = seed.version;
            let telemetry = Arc::clone(telemetry);
            std::thread::Builder::new()
                .name(format!("dalvq-serve-reducer-{s}"))
                .spawn(move || {
                    run_serving_reducer(
                        queue_rx,
                        blob,
                        store,
                        counters,
                        shard_merges,
                        w0,
                        publish_every,
                        merges0,
                        telemetry,
                    )
                })
                .expect("spawning serve reducer thread")
        };

        let worker_shards = shard_data.split(cfg.m);
        let mut ingest_txs = Vec::with_capacity(cfg.m);
        let mut workers = Vec::with_capacity(cfg.m);
        for (i, shard) in worker_shards.into_iter().enumerate() {
            let wid = s * cfg.m + i; // fleet-global worker id
            let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(serve.ingest_queue);
            ingest_txs.push(tx);
            let params = ServeWorkerParams {
                worker_id: wid,
                shard,
                w0: seed.w0.clone(),
                schedule: cfg.vq.schedule,
                tau: cfg.scheme.tau(),
                points_per_exchange: serve.points_per_exchange,
                point_compute: serve.point_compute,
                absorb_per_chunk: serve.absorb_per_chunk,
                engine_spec: cfg.engine.clone(),
                ready: Arc::clone(&ready),
                stop: Arc::clone(&stop),
                go: Arc::clone(&go),
                sync_exchange: serve.sync_exchange,
                max_points: serve.max_points_per_worker,
                t0: seed.t0,
                fold_base: seed.version,
                queue_depth: Arc::clone(&queue_depth),
                telemetry: Arc::clone(telemetry),
            };
            let q = queue.clone().with_latency(LatencyInjector::new(
                serve.service_latency,
                serve.latency_jitter,
                serve.drop_prob,
                cfg.seed ^ ((wid as u64) << 8),
            ));
            let b = blob.clone().with_latency(LatencyInjector::new(
                serve.service_latency,
                serve.latency_jitter,
                0.0, // downloads are request/response; loss shows as latency
                cfg.seed ^ ((wid as u64) << 8) ^ 1,
            ));
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dalvq-serve-worker-{wid}"))
                    .spawn(move || run_serve_worker(params, rx, q, b))
                    .expect("spawning serve worker thread"),
            );
        }

        shards.push(ShardFleet {
            store,
            merges,
            ingested: Arc::new(AtomicU64::new(seed.ingested)),
            shed: Arc::new(AtomicU64::new(seed.shed)),
            queue_depth,
            ingest_txs: Mutex::new(ingest_txs),
            ingest_cursor: AtomicUsize::new(0),
            fleet: Mutex::new(Some(Fleet {
                workers,
                reducer,
                queue_template: queue,
            })),
        });
    }
    ready.wait(); // engines built; the epoch is live

    Ok(Epoch { router, router_version, shards, stop, go, base_versions })
}

/// Seeds for a new epoch's fleets out of restored durable state.
fn seeds_from_restored(
    restored: &RestoredState,
    serve: &ServeConfig,
    m: usize,
) -> Vec<ShardSeed> {
    let ppe = serve.points_per_exchange as u64;
    restored
        .shards
        .iter()
        .map(|st| {
            // The saved cursor counts the shard's folded points; spread
            // it across M workers, snapped down to an exchange boundary.
            // The fold clock resumes from the saved *version* — the folds
            // the saved codebook actually contains. The file's `merges`
            // field can run ahead of it (unpublished folds at checkpoint
            // time, or a racy counter sample) and is diagnostic only.
            ShardSeed {
                w0: st.codebook.clone(),
                version: st.version,
                t0: st.rng_cursor / m as u64 / ppe * ppe,
                ingested: st.ingested,
                shed: st.shed,
            }
        })
        .collect()
}

/// Seeds that reproduce a quiesced epoch's fleets from their in-memory
/// final snapshots — the rebalance failure path: revive exactly what the
/// stores still serve, without touching the (possibly torn) disk state.
fn seeds_from_epoch(ep: &Epoch, serve: &ServeConfig, m: usize) -> Vec<ShardSeed> {
    let ppe = serve.points_per_exchange as u64;
    ep.shards
        .iter()
        .map(|fleet| {
            let snap = fleet.store.load();
            ShardSeed {
                w0: snap.codebook.clone(),
                version: snap.version,
                // Same cursor arithmetic as a disk restore: the fold
                // sequence represents version * ppe points, spread over M
                // workers and snapped to an exchange boundary.
                t0: snap.version * ppe / m as u64 / ppe * ppe,
                ingested: fleet.ingested.load(Ordering::Relaxed),
                shed: fleet.shed.load(Ordering::Relaxed),
            }
        })
        .collect()
}

/// Hand an epoch's shard stores and counters to a fresh background
/// checkpointer stamped with the epoch's partition version; its manifest
/// writes bump the shared `generation` clock.
fn spawn_checkpointer(
    dir: &Path,
    epoch: &Epoch,
    last_checkpoint: &Arc<Vec<AtomicU64>>,
    generation: &Arc<AtomicU64>,
    telemetry: &Telemetry,
    cfg: &ExperimentConfig,
    serve: &ServeConfig,
) -> Checkpointer {
    Checkpointer::spawn(
        CheckpointSpec {
            dir: dir.to_path_buf(),
            checkpoint_every: serve.checkpoint_every,
            points_per_exchange: serve.points_per_exchange,
            kappa: cfg.vq.kappa,
            dim: cfg.dim(),
            router_version: epoch.router_version,
            generation: Arc::clone(generation),
            journal: Some(Arc::clone(telemetry.journal())),
        },
        epoch
            .shards
            .iter()
            .map(|f| persist::ShardSource {
                store: Arc::clone(&f.store),
                merges: Arc::clone(&f.merges),
                ingested: Arc::clone(&f.ingested),
                shed: Arc::clone(&f.shed),
            })
            .collect(),
        Arc::clone(last_checkpoint),
    )
}

/// The skew monitor: a background thread that watches the current epoch's
/// per-shard ingest counters and triggers [`VqService::rebalance`] when
/// the max/mean imbalance exceeds `rebalance_skew` — after at least
/// `rebalance_min_folds` folds have landed in the epoch, so the shard
/// codebooks have actually adapted to the load the retrainer will weight
/// by. Holds only a `Weak` handle: the monitor never keeps a dropped
/// service alive.
fn spawn_monitor(service: &Arc<VqService>) -> JoinHandle<()> {
    let weak: Weak<VqService> = Arc::downgrade(service);
    let skew = service.serve.rebalance_skew;
    let min_folds = service.serve.rebalance_min_folds;
    std::thread::Builder::new()
        .name("dalvq-rebalance-monitor".into())
        .spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_millis(50));
            let Some(svc) = weak.upgrade() else { return };
            if svc.closing.load(Ordering::Acquire) {
                return;
            }
            let ep = svc.current();
            let folds: u64 = ep
                .shards
                .iter()
                .zip(&ep.base_versions)
                .map(|(f, b)| f.store.version().saturating_sub(*b))
                .sum();
            if folds < min_folds {
                continue;
            }
            let ingests: Vec<u64> =
                ep.shards.iter().map(|f| f.ingested.load(Ordering::Relaxed)).collect();
            let total: u64 = ingests.iter().sum();
            // A ratio over a tiny sample is noise, not skew: wait for a
            // statistically meaningful epoch sample (64 points per shard
            // on average bounds multinomial noise well below any
            // reasonable trigger) before judging balance — otherwise a
            // freshly swapped epoch could be churned by its first batch.
            if total < 64 * ingests.len() as u64 {
                continue;
            }
            if super::loadgen::max_over_mean(&ingests) < skew {
                continue;
            }
            drop(ep);
            if let Err(e) = svc.rebalance() {
                // `closing` raced us, or the disk failed — back off so a
                // persistent failure cannot hot-loop the quiesce path.
                if !svc.closing.load(Ordering::Acquire) {
                    eprintln!(
                        "dalvq rebalance monitor: auto-rebalance failed \
                         (will retry): {e:#}"
                    );
                    std::thread::sleep(std::time::Duration::from_secs(1));
                }
            }
        })
        .expect("spawning rebalance monitor thread")
}

/// Load durable state for a warm start and validate it against the
/// deployment config. `Ok(None)` = cold start (no manifest yet). Any
/// shape mismatch — shard count, total kappa, dim — is a hard error:
/// seeding a fleet from a codebook of the wrong shape would corrupt it
/// silently, and retraining over state the operator asked us to keep
/// would be data loss.
fn load_restore(
    dir: &Path,
    cfg: &ExperimentConfig,
    serve: &ServeConfig,
) -> Result<Option<RestoredState>> {
    // The serving startup owns the state dir: sweep stale `.tmp` files
    // from interrupted checkpoints before loading. (The shared loader
    // itself never removes anything — `dalvq state inspect` reads
    // through it against possibly-live directories.)
    persist::sweep_tmp(dir);
    let Some(state) = persist::load_state(dir)
        .with_context(|| format!("restoring state from {}", dir.display()))?
    else {
        return Ok(None);
    };
    let m = &state.manifest;
    if m.shards != serve.shards || m.kappa != cfg.vq.kappa || m.dim != cfg.dim() {
        return Err(anyhow!(
            "state dir {} was written by a deployment with shards={} \
             kappa={} dim={}, but this config has shards={} kappa={} dim={}; \
             pass a matching config or a fresh --state-dir",
            dir.display(),
            m.shards,
            m.kappa,
            m.dim,
            serve.shards,
            cfg.vq.kappa,
            cfg.dim()
        ));
    }
    // The saved RNG cursors are only exact when the exchange window is
    // unchanged (each fold = points_per_exchange points); a silent
    // mismatch would resume every schedule at the wrong position.
    if m.points_per_exchange != serve.points_per_exchange {
        return Err(anyhow!(
            "state dir {} was checkpointed at points_per_exchange = {}, but \
             this config uses {}; the saved schedule cursors would be \
             misinterpreted — keep the window or start a fresh --state-dir",
            dir.display(),
            m.points_per_exchange,
            serve.points_per_exchange
        ));
    }
    Ok(Some(state))
}

/// Write an epoch's full durable image: router + every shard's current
/// state + manifest (stamped `generation`). Used for the cold-start
/// bootstrap (the directory must be restorable before the first fold —
/// a service killed seconds after start must still warm-restart cleanly)
/// and to heal the state dir back to a revived partition after a failed
/// rebalance.
fn write_initial_state(
    dir: &Path,
    epoch: &Epoch,
    cfg: &ExperimentConfig,
    serve: &ServeConfig,
    generation: u64,
) -> Result<()> {
    let router_state = RouterState {
        version: epoch.router_version,
        centroids: epoch.router.centroids().clone(),
    };
    persist::write_atomic(dir, persist::ROUTER_FILE, &router_state.encode())?;
    let mut versions = Vec::with_capacity(epoch.shards.len());
    for (s, fleet) in epoch.shards.iter().enumerate() {
        let snap = fleet.store.load();
        let state = ShardState {
            shard: s as u32,
            version: snap.version,
            merges: fleet.merges.load(Ordering::Relaxed),
            rng_cursor: snap.version * serve.points_per_exchange as u64,
            // Live epoch counters (0 on a cold start): the healed image
            // of a revived partition must keep the load the retrainer
            // will weight by.
            ingested: fleet.ingested.load(Ordering::Relaxed),
            shed: fleet.shed.load(Ordering::Relaxed),
            router_version: epoch.router_version,
            codebook: snap.codebook.clone(),
        };
        persist::write_atomic(dir, &persist::shard_file(s), &state.encode())?;
        versions.push(snap.version);
    }
    Manifest {
        format: persist::FORMAT,
        shards: epoch.shards.len(),
        kappa: cfg.vq.kappa,
        dim: cfg.dim(),
        points_per_exchange: serve.points_per_exchange,
        router_version: epoch.router_version,
        generation,
        shard_versions: versions,
    }
    .save(dir)
}

/// Shipped files in the `(name, bytes)` shape the persist layer's
/// bundle codec takes — by move, so a bundle near the frame cap is
/// never copied on adoption.
fn shipped_files(files: Vec<StateFile>) -> Vec<(String, Vec<u8>)> {
    files.into_iter().map(|f| (f.name, f.bytes)).collect()
}

/// Whole files as wire shipment entries (offset 0, full length).
fn whole_state_files(files: Vec<(String, Vec<u8>)>) -> Vec<StateFile> {
    files
        .into_iter()
        .map(|(name, bytes)| StateFile {
            name,
            offset: 0,
            file_len: bytes.len() as u64,
            bytes,
        })
        .collect()
}

/// One chunk's file parts as wire shipment entries.
fn part_state_files(parts: Vec<persist::FilePart>) -> Vec<StateFile> {
    parts
        .into_iter()
        .map(|p| StateFile {
            name: p.name,
            offset: p.offset,
            file_len: p.file_len,
            bytes: p.bytes,
        })
        .collect()
}

/// Build a fleetless epoch out of restored (shipped) state: the shard
/// stores hold the shipped codebooks verbatim at their shipped versions,
/// ingest channels are empty (the service-level follower guard answers
/// writes before routing ever looks here), and there is no fleet to
/// quiesce. The read path cannot tell it from a trained epoch.
fn follower_epoch(restored: &RestoredState, telemetry: &Telemetry) -> Epoch {
    let router = Router::from_centroids(restored.router.centroids.clone());
    let shards = restored
        .shards
        .iter()
        .enumerate()
        .map(|(s, st)| {
            // No fleets means no ingest queues; pin the gauge at 0 so a
            // follower's metrics read coherently.
            let queue_depth =
                telemetry.gauge(&format!("shard.{s}.queue_depth"));
            queue_depth.set(0);
            ShardFleet {
                store: SnapshotStore::with_version(
                    st.codebook.clone(),
                    st.version,
                ),
                merges: Arc::new(AtomicU64::new(st.version)),
                // A follower's per-epoch load counters are its own
                // (always zero — it never ingests); the leader's are
                // visible via the leader's Stats, not echoed here.
                ingested: Arc::new(AtomicU64::new(0)),
                shed: Arc::new(AtomicU64::new(0)),
                queue_depth,
                ingest_txs: Mutex::new(Vec::new()),
                ingest_cursor: AtomicUsize::new(0),
                fleet: Mutex::new(None),
            }
        })
        .collect();
    Epoch {
        router,
        router_version: restored.manifest.router_version,
        shards,
        stop: Arc::new(AtomicBool::new(false)),
        go: Arc::new(AtomicBool::new(true)),
        base_versions: restored.shards.iter().map(|s| s.version).collect(),
    }
}

/// The follower sync loop: a background thread that polls the leader
/// every `sync_every` and adopts new checkpoint generations. Holds only
/// a `Weak` handle (like the skew monitor) and exits on `closing`. A
/// failed poll — leader briefly down, a racing migration — logs and
/// retries on the next tick; the follower keeps serving its current
/// epoch throughout, which is the whole point of asynchronous, delayed
/// state exchange.
///
/// With `--miss-threshold N` armed, `N` *consecutive* failed polls
/// promote this follower from its mirror dir ([`VqService::promote`]);
/// the loop then turns into the demote patrol against the old leader's
/// address. Any successful poll resets the miss count.
fn spawn_follower_sync(service: &Arc<VqService>) -> JoinHandle<()> {
    let weak: Weak<VqService> = Arc::downgrade(service);
    let sync_every = service
        .follower
        .as_ref()
        .expect("spawn_follower_sync on a leader")
        .sync_every;
    let miss_threshold = service.serve.miss_threshold;
    std::thread::Builder::new()
        .name("dalvq-follower-sync".into())
        .spawn(move || loop {
            // Sleep in short slices so shutdown never waits a full
            // sync interval for the join.
            let wake = Instant::now() + sync_every;
            while Instant::now() < wake {
                std::thread::sleep(Duration::from_millis(10).min(sync_every));
                match weak.upgrade() {
                    Some(svc) if !svc.closing.load(Ordering::Acquire) => {}
                    _ => return,
                }
            }
            let Some(svc) = weak.upgrade() else { return };
            if svc.closing.load(Ordering::Acquire) {
                return;
            }
            let Some(f) = svc.follower.as_ref() else { return };
            if f.promoted.load(Ordering::Acquire) {
                // Promoted: no leader to sync from. Patrol the old
                // address instead, so a returning stale leader demotes.
                if !f.patrol_done.load(Ordering::Acquire) {
                    svc.demote_patrol();
                }
                continue;
            }
            match svc.sync_once() {
                Ok(_) => f.misses.store(0, Ordering::Release),
                Err(e) => {
                    let misses = f.misses.fetch_add(1, Ordering::AcqRel) + 1;
                    if !svc.closing.load(Ordering::Acquire) {
                        eprintln!(
                            "dalvq follower: sync with the leader failed \
                             (still serving the last adopted epoch; will \
                             retry): {e:#}"
                        );
                    }
                    if miss_threshold > 0 && misses >= miss_threshold {
                        if let Err(pe) = svc.promote(misses) {
                            eprintln!(
                                "dalvq follower: failover promotion failed \
                                 (will retry next poll): {pe:#}"
                            );
                        }
                    }
                }
            }
        })
        .expect("spawning follower sync thread")
}

/// The `--metrics-file` writer: a background thread that snapshots the
/// telemetry plane every `every` and rewrites `path` with the JSON
/// document. Holds only a `Weak` handle (like the monitor and the sync
/// loop), sleeps in short slices so shutdown never waits a full period,
/// and writes one final snapshot on exit so the file always carries the
/// end-of-life totals. A failed write logs and retries next tick.
///
/// Writes go through the persist layer's temp→fsync→rename protocol, so
/// a reader never sees a partial document — every open of `path` yields
/// either the previous complete snapshot or the new one.
fn spawn_metrics_writer(
    service: &Arc<VqService>,
    path: PathBuf,
    every: Duration,
) -> JoinHandle<()> {
    let weak: Weak<VqService> = Arc::downgrade(service);
    let write = move |svc: &VqService| {
        let budget = svc.serve.journal_capacity;
        let doc = svc.metrics_snapshot(budget).to_json().to_pretty();
        let res = match (path.parent(), path.file_name()) {
            (Some(dir), Some(name)) => persist::write_atomic(
                // `Path::parent` of a bare filename is `""`; write into
                // the working directory, not a directory named "".
                if dir.as_os_str().is_empty() { Path::new(".") } else { dir },
                &name.to_string_lossy(),
                doc.as_bytes(),
            ),
            _ => Err(anyhow!("{} has no file name", path.display())),
        };
        if let Err(e) = res {
            eprintln!(
                "dalvq metrics writer: writing {} failed (will retry): {e:#}",
                path.display()
            );
        }
    };
    std::thread::Builder::new()
        .name("dalvq-metrics-writer".into())
        .spawn(move || loop {
            let wake = Instant::now() + every;
            while Instant::now() < wake {
                std::thread::sleep(Duration::from_millis(10).min(every));
                match weak.upgrade() {
                    Some(svc) if !svc.closing.load(Ordering::Acquire) => {}
                    Some(svc) => {
                        write(&svc); // final end-of-life snapshot
                        return;
                    }
                    None => return,
                }
            }
            let Some(svc) = weak.upgrade() else { return };
            write(&svc);
            if svc.closing.load(Ordering::Acquire) {
                return;
            }
        })
        .expect("spawning metrics writer thread")
}

/// Pad a shard's bootstrap region up to `min_pts` points: cycle the
/// region's own points, or fall back to the dataset prefix for an empty
/// cell (possible only in pathological router fits).
fn ensure_min_points(
    mut part: Vec<f32>,
    dim: usize,
    min_pts: usize,
    fallback: &[f32],
) -> Vec<f32> {
    if part.is_empty() {
        let take = min_pts.min(fallback.len() / dim);
        part.extend_from_slice(&fallback[..take * dim]);
    }
    let have = part.len() / dim;
    let mut i = 0usize;
    while part.len() / dim < min_pts {
        let s = i % have;
        part.extend_from_within(s * dim..(s + 1) * dim);
        i += 1;
    }
    part
}

/// The serving reducer: the cloud reducer's fold-and-put loop plus epoch
/// publication for the read path. One per shard. `initial_merges` seeds
/// the fold clock on a warm restart or migration, so published versions
/// continue the saved sequence instead of restarting at 1.
///
/// With tracing armed, a sampled fold records a `reduce.cycle` trace:
/// `reduce.merge` covers the delta fold plus the blob put that makes it
/// visible to workers, `reduce.publish` the read-epoch publication when
/// this fold crosses a `publish_every` boundary.
#[allow(clippy::too_many_arguments)]
fn run_serving_reducer(
    rx: mpsc::Receiver<DeltaMsg>,
    mut blob: BlobHandle,
    store: Arc<SnapshotStore>,
    counters: Arc<ServeCounters>,
    shard_merges: Arc<AtomicU64>,
    w0: Codebook,
    publish_every: u64,
    initial_merges: u64,
    telemetry: Arc<Telemetry>,
) -> Result<(u64, Codebook)> {
    let tracer = telemetry.tracer();
    let mut w_srd = w0;
    let mut merges: u64 = initial_merges;
    for msg in rx.iter() {
        let mut tb = tracer.begin();
        let root = match tb.as_mut() {
            Some(t) => t.begin("reduce.cycle", NO_PARENT),
            None => NO_PARENT,
        };
        let merge_span = tb.as_mut().map(|t| t.begin("reduce.merge", root));
        w_srd.apply_delta(&msg.delta);
        merges += 1;
        shard_merges.store(merges, Ordering::Relaxed);
        counters.merges.fetch_add(1, Ordering::Relaxed);
        blob.put(w_srd.clone(), merges)?;
        if let (Some(t), Some(id)) = (tb.as_mut(), merge_span) {
            t.end(id);
        }
        if merges % publish_every == 0 {
            let publish_span =
                tb.as_mut().map(|t| t.begin("reduce.publish", root));
            store.publish(w_srd.clone(), merges);
            if let (Some(t), Some(id)) = (tb.as_mut(), publish_span) {
                t.end(id);
            }
        }
        if let Some(mut t) = tb {
            t.end(root);
            tracer.commit(t);
        }
    }
    // Queue closed: one final epoch so readers see everything folded.
    store.publish(w_srd.clone(), merges);
    Ok((merges, w_srd))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchemeConfig;
    use crate::sim::DelayModel;
    use crate::vq::Schedule;

    pub(crate) fn tiny_cfg(m: usize) -> (ExperimentConfig, ServeConfig) {
        let mut cfg = ExperimentConfig::default();
        cfg.m = m;
        cfg.data.mixture.components = 4;
        cfg.data.mixture.dim = 2;
        cfg.data.n_total = 2_000;
        cfg.data.eval_points = 256;
        cfg.vq.kappa = 4;
        cfg.vq.schedule = Schedule::Constant { eps0: 0.01 };
        cfg.scheme = SchemeConfig::AsyncDelta {
            tau: 10,
            up_delay: DelayModel::Instant,
            down_delay: DelayModel::Instant,
        };
        let mut serve = ServeConfig::default();
        serve.points_per_exchange = 50;
        // pace gently so the test fleet doesn't saturate small CI hosts
        serve.point_compute = 2e-6;
        (cfg, serve)
    }

    #[test]
    fn service_trains_while_serving_and_shuts_down_cleanly() {
        let (cfg, serve) = tiny_cfg(2);
        let svc = VqService::start(&cfg, &serve).unwrap();
        let v0 = svc.version();
        let eval = cfg.data.mixture.eval_sample(256, cfg.seed);
        let (_, c0) = svc.query_distortion(&eval);
        // wait for some folds to land
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while svc.version() < v0 + 5 {
            assert!(
                std::time::Instant::now() < deadline,
                "no folds published within 10s"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let snap = svc.snapshot();
        assert!(snap.version >= v0 + 5);
        assert!(snap.codebook.is_finite());
        // constant-step training on the same mixture must not blow up C
        let (_, c1) = svc.query_distortion(&eval);
        assert!(c1 < c0 * 2.0 + 1.0, "{c0} -> {c1}");
        let out = svc.shutdown().unwrap();
        assert!(out.merges >= 5);
        assert!(out.final_shared.is_finite());
        let trained: u64 = out.workers.iter().map(|w| w.points_trained).sum();
        assert!(trained > 0);
    }

    #[test]
    fn ingest_validates_shape_and_counts() {
        let (cfg, serve) = tiny_cfg(1);
        let svc = VqService::start(&cfg, &serve).unwrap();
        assert!(svc.ingest(&[1.0, 2.0, 3.0]).is_err()); // dim = 2
        let (acc, shed) = svc.ingest(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(acc + shed, 2);
        assert_eq!(svc.ingest(&[]).unwrap(), (0, 0));
        let stats = svc.stats();
        assert_eq!(stats.ingested + stats.ingest_shed, 2);
        assert_eq!(stats.workers, 1);
        assert_eq!(stats.shards, 1);
        assert_eq!(stats.dim, 2);
        assert_eq!(stats.router_version, 0);
        assert_eq!(stats.rebalances, 0);
        // the per-shard epoch counters tally with the totals
        assert_eq!(stats.shard_ingest.iter().sum::<u64>(), stats.ingested);
        assert_eq!(stats.shard_shed.iter().sum::<u64>(), stats.ingest_shed);
        svc.shutdown().unwrap();
    }

    #[test]
    fn sharded_service_routes_queries_and_ingest() {
        let (mut cfg, mut serve) = tiny_cfg(1);
        cfg.vq.kappa = 8; // 2 prototypes per shard
        serve.shards = 4;
        serve.probe_n = 2;
        let svc = VqService::start(&cfg, &serve).unwrap();
        assert_eq!(svc.shards(), 4);
        assert_eq!(svc.router().shards(), 4);

        let eval = cfg.data.mixture.eval_sample(128, cfg.seed);
        let (_, codes, dists) = svc.query_nearest(&eval);
        assert_eq!(codes.len(), 128);
        // global codes span the whole kappa range, not one shard's
        assert!(codes.iter().all(|&c| (c as usize) < 8));
        assert!(dists.iter().all(|d| d.is_finite() && *d >= 0.0));

        // ingest fans out across shards without error
        let (acc, shed) = svc.ingest(&eval).unwrap();
        assert_eq!(acc + shed, 128);

        let stats = svc.stats();
        assert_eq!(stats.shards, 4);
        assert_eq!(stats.probe_n, 2);
        assert_eq!(stats.shard_versions.len(), 4);
        assert_eq!(stats.shard_merges.len(), 4);
        assert_eq!(stats.shard_ingest.len(), 4);
        assert_eq!(stats.shard_ingest.iter().sum::<u64>(), acc);
        assert_eq!(stats.kappa, 8);

        // Quiesce before cross-probe comparisons: reads must come from
        // the identical (now frozen) epochs, not two loads of a moving
        // target. The read path stays up after shutdown by design.
        let out = svc.shutdown().unwrap();
        assert_eq!(out.shards.len(), 4);
        assert_eq!(out.final_shared.kappa(), 8);

        // exhaustive probe can only improve (or equal) every distance
        let (_, _, routed) = svc.query_nearest_probed(&eval, 2);
        let (_, _, oracle) = svc.query_nearest_probed(&eval, 4);
        for (d2, dfull) in routed.iter().zip(&oracle) {
            assert!(dfull <= d2, "oracle worse than probe: {dfull} > {d2}");
        }

        // the merged snapshot concatenates shard codebooks in code order
        let snap = svc.snapshot();
        assert_eq!(snap.codebook.kappa(), 8);
        for (s, shard_snap) in svc.snapshots().iter().enumerate() {
            assert_eq!(
                &snap.codebook.flat()[s * 2 * 2..(s + 1) * 2 * 2],
                shard_snap.codebook.flat()
            );
        }
    }

    #[test]
    fn rebalance_without_state_dir_is_a_clean_error() {
        let (cfg, serve) = tiny_cfg(1);
        let svc = VqService::start(&cfg, &serve).unwrap();
        let err = format!("{:#}", svc.rebalance().unwrap_err());
        assert!(err.contains("state-dir"), "{err}");
        // the service keeps serving after the refused rebalance
        let eval = cfg.data.mixture.eval_sample(16, cfg.seed);
        let (_, codes) = svc.query_encode(&eval);
        assert_eq!(codes.len(), 16);
        svc.shutdown().unwrap();
    }

    #[test]
    fn manual_rebalance_swaps_the_epoch_and_keeps_serving() {
        let dir = std::env::temp_dir().join(format!(
            "dalvq-svc-rebalance-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let (mut cfg, mut serve) = tiny_cfg(1);
        cfg.vq.kappa = 8;
        serve.shards = 4;
        serve.probe_n = 2;
        serve.state_dir = Some(dir.clone());
        let svc = VqService::start(&cfg, &serve).unwrap();
        let eval = cfg.data.mixture.eval_sample(128, cfg.seed);
        svc.ingest(&eval).unwrap();

        assert_eq!(svc.router_version(), 0);
        let out = svc.rebalance().unwrap();
        assert_eq!(out.router_version, 1);
        assert_eq!(out.shard_versions.len(), 4);
        assert_eq!(svc.router_version(), 1);
        let stats = svc.stats();
        assert_eq!(stats.rebalances, 1);
        assert_eq!(stats.router_version, 1);
        // the fold-clock invariant survives the version jump
        assert!(stats.merges >= stats.version, "{stats:?}");
        // per-epoch load counters reset with the new partition
        assert_eq!(stats.shard_ingest, vec![0; 4]);

        // the new epoch answers queries and accepts ingest
        let (_, codes, dists) = svc.query_nearest(&eval);
        assert_eq!(codes.len(), 128);
        assert!(codes.iter().all(|&c| (c as usize) < 8));
        assert!(dists.iter().all(|d| d.is_finite()));
        let (acc, shed) = svc.ingest(&eval).unwrap();
        assert_eq!(acc + shed, 128);

        svc.shutdown().unwrap();
        // shutdown after a rebalance leaves the bumped partition on disk
        let state = persist::load_state(&dir).unwrap().unwrap();
        assert_eq!(state.manifest.router_version, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn follower_epoch_serves_restored_state_verbatim() {
        // A fleetless epoch built from restored state must expose the
        // shipped codebooks at the shipped versions under the shipped
        // router — the read path cannot tell it from a trained epoch.
        let restored = RestoredState {
            manifest: Manifest {
                format: persist::FORMAT,
                shards: 2,
                kappa: 4,
                dim: 2,
                points_per_exchange: 50,
                router_version: 3,
                generation: 12,
                shard_versions: vec![8, 9],
            },
            router: RouterState {
                version: 3,
                centroids: Codebook::from_flat(
                    2,
                    2,
                    vec![-5.0, -5.0, 5.0, 5.0],
                ),
            },
            shards: vec![
                ShardState {
                    shard: 0,
                    version: 8,
                    merges: 8,
                    rng_cursor: 400,
                    ingested: 100,
                    shed: 0,
                    router_version: 3,
                    codebook: Codebook::from_flat(2, 2, vec![-5.0; 4]),
                },
                ShardState {
                    shard: 1,
                    version: 9,
                    merges: 9,
                    rng_cursor: 450,
                    ingested: 50,
                    shed: 2,
                    router_version: 3,
                    codebook: Codebook::from_flat(2, 2, vec![5.0; 4]),
                },
            ],
        };
        let ep = follower_epoch(&restored, &Telemetry::new(8));
        assert_eq!(ep.router_version, 3);
        assert_eq!(ep.shards.len(), 2);
        assert_eq!(ep.base_versions, vec![8, 9]);
        for (s, fleet) in ep.shards.iter().enumerate() {
            let snap = fleet.store.load();
            assert_eq!(snap.version, restored.shards[s].version);
            assert_eq!(
                snap.codebook.flat(),
                restored.shards[s].codebook.flat()
            );
            // a follower's own load counters start at zero
            assert_eq!(fleet.ingested.load(Ordering::Relaxed), 0);
            assert!(fleet.ingest_txs.lock().unwrap().is_empty());
            assert!(fleet.fleet.lock().unwrap().is_none());
        }
    }

    #[test]
    fn timed_query_agrees_with_the_untimed_path() {
        let (mut cfg, mut serve) = tiny_cfg(1);
        cfg.vq.kappa = 8;
        serve.shards = 4;
        serve.probe_n = 2;
        let svc = VqService::start(&cfg, &serve).unwrap();
        // Quiesce first so both reads see identical frozen snapshots
        // (the read path stays up after shutdown by design).
        svc.shutdown().unwrap();
        let eval = cfg.data.mixture.eval_sample(64, cfg.seed);
        let (version, codes, dists) = svc.query_nearest_probed(&eval, 2);
        let timed = svc.query_nearest_timed(&eval, 2);
        assert_eq!(timed.version, version);
        assert_eq!(timed.codes, codes);
        assert_eq!(timed.dists, dists);
        // the stage timings landed in the histograms
        let snap = svc.metrics_snapshot(0);
        let hist = |name: &str| {
            snap.hists
                .iter()
                .find(|(n, _)| n == name)
                .unwrap_or_else(|| panic!("no histogram {name}"))
                .1
                .clone()
        };
        assert_eq!(hist("query.route_us").count, 1);
        assert_eq!(hist("query.scan_us").count, 1);
    }

    #[test]
    fn fused_scan_matches_the_scalar_per_point_oracle() {
        // The shard-grouped fused scan must be bit-identical to the
        // pre-batching loop — probe one point at a time via nearest_one,
        // merge in probe order with strict `<` — replicated here inline.
        let (mut cfg, mut serve) = tiny_cfg(1);
        cfg.vq.kappa = 8;
        serve.shards = 4;
        serve.probe_n = 2;
        let svc = VqService::start(&cfg, &serve).unwrap();
        // Quiesce so both reads see identical frozen snapshots (the read
        // path stays up after shutdown by design).
        svc.shutdown().unwrap();
        let eval = cfg.data.mixture.eval_sample(128, cfg.seed);
        for probe_n in [1, 2, 4] {
            let (_, codes, dists) = svc.query_nearest_probed(&eval, probe_n);
            let router = svc.router();
            let snaps = svc.snapshots();
            let kappa_shard = svc.kappa() / snaps.len();
            let mut probes = Vec::new();
            for (i, z) in eval.chunks_exact(svc.dim()).enumerate() {
                router.probe_into(z, probe_n, &mut probes);
                let mut best_code = 0u32;
                let mut best_d = f32::INFINITY;
                for &s in &probes {
                    let (local, d) = snaps[s].nearest_one(z);
                    if d < best_d {
                        best_d = d;
                        best_code = (s * kappa_shard) as u32 + local;
                    }
                }
                assert_eq!(codes[i], best_code, "code at point {i}");
                assert_eq!(
                    dists[i].to_bits(),
                    best_d.to_bits(),
                    "distance not bit-identical at point {i}"
                );
            }
        }
    }

    #[test]
    fn ensure_min_points_pads_and_falls_back() {
        let fallback: Vec<f32> = (0..12).map(|i| i as f32).collect(); // 6 pts dim 2
        // enough points: untouched
        let p = ensure_min_points(vec![1.0, 2.0, 3.0, 4.0], 2, 2, &fallback);
        assert_eq!(p, vec![1.0, 2.0, 3.0, 4.0]);
        // short: cycle-padded from its own points
        let p = ensure_min_points(vec![1.0, 2.0], 2, 3, &fallback);
        assert_eq!(p, vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
        // empty: seeded from the fallback prefix
        let p = ensure_min_points(Vec::new(), 2, 2, &fallback);
        assert_eq!(p, vec![0.0, 1.0, 2.0, 3.0]);
    }
}
