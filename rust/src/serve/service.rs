//! The in-process service: `S` independent shard fleets (workers + queue +
//! blob + reducer + [`SnapshotStore`]) behind a coarse-quantizer
//! [`Router`].
//!
//! Training topology per shard is exactly the cloud runtime's (eq. 9 /
//! CloudDALVQ): `M` worker threads exchange displacements through the
//! shard's queue and blob services without barriers, and a dedicated
//! reducer folds whatever arrives next, epoch-swapping immutable snapshots
//! into the shard's store. Shards never synchronize with each other —
//! Patra's asynchronous-LVQ analysis holds per shard, and the router is
//! the only cross-shard structure (frozen after its bootstrap k-means
//! pass). Queries multi-probe the `probe_n` nearest shards; ingest routes
//! every point to its owning shard's workers. With `shards = 1` the
//! service collapses to the original single-fleet deployment, bit-for-bit
//! (same seeds, same data order).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Barrier, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::cloud::{
    BlobHandle, BlobService, DeltaMsg, LatencyInjector, QueueService,
};
use crate::config::{ExperimentConfig, ServeConfig};
use crate::data::Dataset;
use crate::persist::{
    self, Checkpointer, Manifest, RestoredState, RouterState, ShardState,
};
use crate::vq::{init_codebook, Codebook};

use super::router::Router;
use super::snapshot::{Snapshot, SnapshotStore};
use super::worker::{run_serve_worker, ServeWorkerOutcome, ServeWorkerParams};

/// Live counters, shared between the fleets and the front-end.
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Ingested points accepted into worker queues (all shards).
    pub ingested: AtomicU64,
    /// Ingested points shed because a worker's queue was full.
    pub ingest_shed: AtomicU64,
    /// Queries answered (all read ops; maintained by the front-end).
    pub queries: AtomicU64,
    /// Deltas folded across every shard's reducer (may run ahead of the
    /// published snapshot versions when `publish_every > 1`).
    pub merges: AtomicU64,
}

/// A point-in-time view of [`ServeCounters`] plus service shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeStats {
    /// Sum of per-shard snapshot versions (monotone; the global freshness
    /// clock of the service).
    pub version: u64,
    /// Total prototypes across all shards.
    pub kappa: usize,
    pub dim: usize,
    /// Total workers across all shards.
    pub workers: usize,
    pub shards: usize,
    pub probe_n: usize,
    /// Reducer folds to date, all shards (>= version; they differ when
    /// reducers publish every `publish_every` folds).
    pub merges: u64,
    pub ingested: u64,
    pub ingest_shed: u64,
    pub queries: u64,
    /// Published snapshot version per shard.
    pub shard_versions: Vec<u64>,
    /// Reducer fold count per shard.
    pub shard_merges: Vec<u64>,
    /// Durable state directory (`None` when the service runs without
    /// persistence).
    pub state_dir: Option<String>,
    /// Last checkpointed version per shard (empty without persistence).
    pub last_checkpoint: Vec<u64>,
}

/// What one shard's fleet reports at shutdown.
#[derive(Debug)]
pub struct ShardOutcome {
    pub shard: usize,
    /// Deltas folded by this shard's reducer over the service lifetime.
    pub merges: u64,
    /// The shard's final shared codebook (`kappa/S` prototypes).
    pub final_shared: Codebook,
}

/// What the whole service reports at shutdown.
#[derive(Debug)]
pub struct ServeOutcome {
    /// Every worker, shard-major order.
    pub workers: Vec<ServeWorkerOutcome>,
    /// Total deltas folded across shards.
    pub merges: u64,
    /// The global codebook: shard codebooks concatenated in shard order
    /// (row `s * kappa/S + j` is shard `s`'s prototype `j`, matching the
    /// global codes queries return).
    pub final_shared: Codebook,
    pub shards: Vec<ShardOutcome>,
}

/// One shard's training fleet handles — taken exactly once at shutdown.
struct Fleet {
    workers: Vec<JoinHandle<Result<ServeWorkerOutcome>>>,
    reducer: JoinHandle<Result<(u64, Codebook)>>,
    /// Held so the queue stays open until shutdown drops it.
    queue_template: crate::cloud::QueueHandle,
}

/// One shard: an independent eq.-9 fleet plus its publication store.
struct ShardFleet {
    store: Arc<SnapshotStore>,
    merges: Arc<AtomicU64>,
    /// Cloned under a short lock per ingest call; cleared at shutdown.
    ingest_txs: Mutex<Vec<mpsc::SyncSender<Vec<f32>>>>,
    ingest_cursor: AtomicUsize,
    fleet: Mutex<Option<Fleet>>,
}

/// The running service. Queries go through the `query_*` methods (which
/// route through the coarse quantizer); ingestion through
/// [`VqService::ingest`]; the TCP front-end ([`super::Server`]) is a thin
/// adapter over exactly these methods.
///
/// Shutdown takes `&self` (the service is normally shared behind an
/// `Arc` with connection handlers), so callers never need to reclaim
/// unique ownership from in-flight connections.
pub struct VqService {
    router: Router,
    shards: Vec<ShardFleet>,
    counters: Arc<ServeCounters>,
    dim: usize,
    /// Total prototypes across shards.
    kappa: usize,
    /// Prototypes per shard (`kappa / S`).
    kappa_shard: usize,
    workers_per_shard: usize,
    probe_n: usize,
    go: Arc<AtomicBool>,
    stop: Arc<AtomicBool>,
    /// Durable state directory (None = no persistence).
    state_dir: Option<PathBuf>,
    /// Last checkpointed version per shard (always `S`-sized; only
    /// meaningful with `state_dir`).
    last_checkpoint: Arc<Vec<AtomicU64>>,
    /// The background checkpointer; taken at shutdown.
    checkpointer: Mutex<Option<Checkpointer>>,
}

impl VqService {
    /// Build the router and every shard fleet, then start serving. Blocks
    /// until all `S * M` workers have built their engines and passed the
    /// ready barrier, so the first query already sees a live system.
    pub fn start(cfg: &ExperimentConfig, serve: &ServeConfig) -> Result<VqService> {
        cfg.validate()?;
        serve.validate(cfg)?;

        let dim = cfg.dim();
        let s_count = serve.shards;
        let kappa_shard = cfg.vq.kappa / s_count;
        let dataset = cfg.data.mixture.dataset(cfg.data.n_total, cfg.seed);

        // Warm restart: load and validate durable state before anything
        // is built (a mismatched state dir must fail here, loudly, not
        // seed a fleet with the wrong shapes).
        let restored = match &serve.state_dir {
            Some(dir) => load_restore(dir, cfg, serve)?,
            None => None,
        };

        // The coarse quantizer: restored verbatim on a warm start (a
        // retrained router would repartition the space and orphan every
        // saved shard codebook); otherwise a short k-means pass over a
        // bootstrap sample (prefix of the dataset — already i.i.d. from
        // the mixture), then frozen for the service lifetime.
        let router = match &restored {
            Some(r) => Router::from_centroids(r.router.centroids.clone()),
            None => {
                let sample_pts = serve.router_sample.min(dataset.len());
                Router::train(
                    &dataset.flat()[..sample_pts * dim],
                    dim,
                    s_count,
                    serve.router_iters,
                    cfg.seed,
                )
            }
        };
        let parts = router.partition(dataset.flat());

        let counters = Arc::new(ServeCounters::default());
        let stop = Arc::new(AtomicBool::new(false));
        let go = Arc::new(AtomicBool::new(!serve.start_paused));
        let ready = Arc::new(Barrier::new(s_count * cfg.m + 1));

        let mut shards = Vec::with_capacity(s_count);
        for (s, part) in parts.into_iter().enumerate() {
            // A shard's region must be able to seed kappa/S prototypes and
            // feed M workers; a starved cell (rare — the router's k-means
            // balances cells against the mixture) is padded cyclically.
            let min_pts = cfg.m.max(kappa_shard);
            let part = ensure_min_points(part, dim, min_pts, dataset.flat());
            let shard_data = Dataset::new(part, dim);
            // Seed state: the checkpoint on a warm start (codebook,
            // version, fold count, schedule cursor), a fresh init on a
            // cold one.
            let (w0, v0, merges0, t0) = match &restored {
                Some(r) => {
                    let st = &r.shards[s];
                    let ppe = serve.points_per_exchange as u64;
                    // The saved cursor counts the shard's folded points;
                    // spread it across M workers, snapped down to an
                    // exchange boundary.
                    let t0 = st.rng_cursor / cfg.m as u64 / ppe * ppe;
                    // The fold clock resumes from the saved *version* —
                    // the folds the saved codebook actually contains.
                    // The file's `merges` field can run ahead of it
                    // (unpublished folds at checkpoint time, or a racy
                    // counter sample); seeding from it would label
                    // future publishes with folds this codebook never
                    // absorbed.
                    (st.codebook.clone(), st.version, st.version, t0)
                }
                None => {
                    let w0 = init_codebook(
                        cfg.vq.init,
                        kappa_shard,
                        dim,
                        shard_data.flat(),
                        // Distinct init stream per shard; shard 0 keeps
                        // the plain seed so `shards = 1` reproduces the
                        // original deployment.
                        cfg.seed ^ ((s as u64) << 17),
                    );
                    (w0, 0, 0, 0)
                }
            };

            let store = SnapshotStore::with_version(w0.clone(), v0);
            let merges = Arc::new(AtomicU64::new(merges0));
            // Keep the global fold counter cumulative too, so
            // `ServeStats::merges` stays >= the summed versions across a
            // warm restart (the invariant its doc states).
            counters.merges.fetch_add(merges0, Ordering::Relaxed);
            let blob = BlobService::spawn(w0.clone());
            let (queue, queue_rx) = QueueService::create(1024);

            let reducer = {
                let blob = blob.clone();
                let store = Arc::clone(&store);
                let counters = Arc::clone(&counters);
                let shard_merges = Arc::clone(&merges);
                let w0 = w0.clone();
                let publish_every = serve.publish_every;
                std::thread::Builder::new()
                    .name(format!("dalvq-serve-reducer-{s}"))
                    .spawn(move || {
                        run_serving_reducer(
                            queue_rx,
                            blob,
                            store,
                            counters,
                            shard_merges,
                            w0,
                            publish_every,
                            merges0,
                        )
                    })
                    .expect("spawning serve reducer thread")
            };

            let worker_shards = shard_data.split(cfg.m);
            let mut ingest_txs = Vec::with_capacity(cfg.m);
            let mut workers = Vec::with_capacity(cfg.m);
            for (i, shard) in worker_shards.into_iter().enumerate() {
                let wid = s * cfg.m + i; // fleet-global worker id
                let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(serve.ingest_queue);
                ingest_txs.push(tx);
                let params = ServeWorkerParams {
                    worker_id: wid,
                    shard,
                    w0: w0.clone(),
                    schedule: cfg.vq.schedule,
                    tau: cfg.scheme.tau(),
                    points_per_exchange: serve.points_per_exchange,
                    point_compute: serve.point_compute,
                    absorb_per_chunk: serve.absorb_per_chunk,
                    engine_spec: cfg.engine.clone(),
                    ready: Arc::clone(&ready),
                    stop: Arc::clone(&stop),
                    go: Arc::clone(&go),
                    sync_exchange: serve.sync_exchange,
                    max_points: serve.max_points_per_worker,
                    t0,
                    fold_base: merges0,
                };
                let q = queue.clone().with_latency(LatencyInjector::new(
                    serve.service_latency,
                    serve.latency_jitter,
                    serve.drop_prob,
                    cfg.seed ^ ((wid as u64) << 8),
                ));
                let b = blob.clone().with_latency(LatencyInjector::new(
                    serve.service_latency,
                    serve.latency_jitter,
                    0.0, // downloads are request/response; loss shows as latency
                    cfg.seed ^ ((wid as u64) << 8) ^ 1,
                ));
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("dalvq-serve-worker-{wid}"))
                        .spawn(move || run_serve_worker(params, rx, q, b))
                        .expect("spawning serve worker thread"),
                );
            }

            shards.push(ShardFleet {
                store,
                merges,
                ingest_txs: Mutex::new(ingest_txs),
                ingest_cursor: AtomicUsize::new(0),
                fleet: Mutex::new(Some(Fleet {
                    workers,
                    reducer,
                    queue_template: queue,
                })),
            });
        }
        ready.wait(); // engines built; the service is live

        // Persistence: on a cold start write the full initial state
        // (router + shard files + manifest) so the directory is
        // restorable from the first moment, then hand the shard stores to
        // the background checkpointer.
        let last_checkpoint: Arc<Vec<AtomicU64>> = Arc::new(
            (0..s_count)
                .map(|s| {
                    AtomicU64::new(
                        restored.as_ref().map_or(0, |r| r.shards[s].version),
                    )
                })
                .collect(),
        );
        let checkpointer = match &serve.state_dir {
            Some(dir) => {
                if restored.is_none() {
                    write_initial_state(dir, &router, &shards, cfg, serve)?;
                }
                Some(Checkpointer::spawn(
                    dir.clone(),
                    shards.iter().map(|f| Arc::clone(&f.store)).collect(),
                    shards.iter().map(|f| Arc::clone(&f.merges)).collect(),
                    Arc::clone(&last_checkpoint),
                    serve.checkpoint_every,
                    serve.points_per_exchange,
                    cfg.vq.kappa,
                    dim,
                ))
            }
            None => None,
        };

        Ok(VqService {
            router,
            shards,
            counters,
            dim,
            kappa: cfg.vq.kappa,
            kappa_shard,
            workers_per_shard: cfg.m,
            probe_n: serve.probe_n,
            go,
            stop,
            state_dir: serve.state_dir.clone(),
            last_checkpoint,
            checkpointer: Mutex::new(checkpointer),
        })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total prototypes across shards.
    pub fn kappa(&self) -> usize {
        self.kappa
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    pub fn probe_n(&self) -> usize {
        self.probe_n
    }

    /// The frozen coarse quantizer (diagnostics, tests, oracles).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Release a fleet started with `start_paused` (no-op otherwise).
    pub fn resume(&self) {
        self.go.store(true, Ordering::Release);
    }

    /// Current published epoch of one shard.
    pub fn shard_snapshot(&self, s: usize) -> Arc<Snapshot> {
        self.shards[s].store.load()
    }

    /// Current epochs of every shard, in shard order.
    pub fn snapshots(&self) -> Vec<Arc<Snapshot>> {
        self.shards.iter().map(|s| s.store.load()).collect()
    }

    /// A coherent global view: with one shard, the shard's epoch as-is
    /// (O(1) `Arc` clone); with several, a freshly assembled snapshot
    /// whose codebook concatenates the shard codebooks in shard order
    /// (rows match the global codes queries return) and whose version is
    /// the per-shard sum.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        if self.shards.len() == 1 {
            return self.shards[0].store.load();
        }
        let snaps = self.snapshots();
        let mut flat = Vec::with_capacity(self.kappa * self.dim);
        let mut version = 0u64;
        for snap in &snaps {
            flat.extend_from_slice(snap.codebook.flat());
            version += snap.version;
        }
        Arc::new(Snapshot {
            codebook: Codebook::from_flat(self.kappa, self.dim, flat),
            version,
        })
    }

    /// Sum of per-shard versions (lock-free; freshness polling).
    pub fn version(&self) -> u64 {
        self.shards.iter().map(|s| s.store.version()).sum()
    }

    /// Per-shard published versions, in shard order.
    pub fn shard_versions(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.store.version()).collect()
    }

    pub fn counters(&self) -> &Arc<ServeCounters> {
        &self.counters
    }

    /// The durable state directory, when persistence is on.
    pub fn state_dir(&self) -> Option<&Path> {
        self.state_dir.as_deref()
    }

    /// Last checkpointed version per shard (empty without persistence).
    pub fn last_checkpoint(&self) -> Vec<u64> {
        if self.state_dir.is_none() {
            return Vec::new();
        }
        self.last_checkpoint
            .iter()
            .map(|v| v.load(Ordering::Acquire))
            .collect()
    }

    /// Force a checkpoint of every shard that advanced since its last
    /// one; blocks until the files are durable. Returns the per-shard
    /// checkpointed versions (the protocol's `Checkpoint` op lands here).
    pub fn checkpoint_now(&self) -> Result<Vec<u64>> {
        let guard = self.checkpointer.lock().unwrap_or_else(|e| e.into_inner());
        match guard.as_ref() {
            Some(ck) => ck.flush(),
            None => Err(anyhow!(
                "service has no durable state (started without --state-dir)"
            )),
        }
    }

    // -------------------------------------------------------- query path

    /// Quantize: global nearest-prototype code per point, via multi-probe
    /// over the configured `probe_n` shards. Returns the aggregate version
    /// that answered. Global code = `shard * kappa/S + local index`.
    pub fn query_encode(&self, points: &[f32]) -> (u64, Vec<u32>) {
        let (version, codes, _) = self.query_nearest_probed(points, self.probe_n);
        (version, codes)
    }

    /// Nearest prototype per point with squared distances, at the
    /// configured probe width.
    pub fn query_nearest(&self, points: &[f32]) -> (u64, Vec<u32>, Vec<f32>) {
        self.query_nearest_probed(points, self.probe_n)
    }

    /// Nearest prototype per point, probing the `probe_n` closest shards
    /// (clamped to `1..=S`). `probe_n = S` is the exhaustive oracle the
    /// drift suite compares routed answers against.
    pub fn query_nearest_probed(
        &self,
        points: &[f32],
        probe_n: usize,
    ) -> (u64, Vec<u32>, Vec<f32>) {
        assert_eq!(points.len() % self.dim, 0, "points not a multiple of dim");
        let snaps = self.snapshots();
        let version = snaps.iter().map(|s| s.version).sum();
        let n = points.len() / self.dim;
        let mut codes = Vec::with_capacity(n);
        let mut dists = Vec::with_capacity(n);
        let mut probes = Vec::with_capacity(probe_n);
        for z in points.chunks_exact(self.dim) {
            self.router.probe_into(z, probe_n, &mut probes);
            let mut best_code = 0u32;
            let mut best_d = f32::INFINITY;
            for &s in &probes {
                let (local, d) = snaps[s].nearest_one(z);
                if d < best_d {
                    best_d = d;
                    best_code = (s * self.kappa_shard) as u32 + local;
                }
            }
            codes.push(best_code);
            dists.push(best_d);
        }
        (version, codes, dists)
    }

    /// Normalized empirical distortion of `points` (paper eq. 2) under the
    /// sharded codebook, at the configured probe width. Empty input is a
    /// defined 0.0.
    pub fn query_distortion(&self, points: &[f32]) -> (u64, f64) {
        let (version, _codes, dists) = self.query_nearest_probed(points, self.probe_n);
        if dists.is_empty() {
            return (version, 0.0);
        }
        let sum: f64 = dists.iter().map(|d| *d as f64).sum();
        (version, sum / dists.len() as f64)
    }

    // ------------------------------------------------------- ingest path

    /// Feed points into the training stream. Each point is routed to the
    /// shard owning its coarse cell, then sharded round-robin across that
    /// fleet's workers; a full worker queue sheds its sub-batch
    /// (at-most-once ingestion — the stochastic algorithm tolerates loss,
    /// and blocking here would couple ingest pressure to query latency).
    /// Returns `(accepted, shed)` point counts.
    pub fn ingest(&self, points: &[f32]) -> Result<(u64, u64)> {
        if points.is_empty() {
            return Ok((0, 0));
        }
        if points.len() % self.dim != 0 {
            return Err(anyhow!(
                "ingest batch of {} floats is not a multiple of dim {}",
                points.len(),
                self.dim
            ));
        }
        // Resolve every destination before sending anything: the reply
        // must stay all-or-nothing with respect to shutdown — it may never
        // claim points were accepted on one shard and then error on the
        // next (the pre-sharding path had exactly one send, so this was
        // free; with a fan-out it has to be a two-phase walk).
        let mut sends = Vec::new();
        for (s, part) in self.router.partition(points).into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            let shard = &self.shards[s];
            let tx = {
                let txs = shard.ingest_txs.lock().unwrap_or_else(|e| e.into_inner());
                if txs.is_empty() {
                    return Err(anyhow!("service is shutting down"));
                }
                let i = shard.ingest_cursor.fetch_add(1, Ordering::Relaxed) % txs.len();
                txs[i].clone()
            };
            sends.push((part, tx));
        }
        let mut accepted = 0u64;
        let mut shed = 0u64;
        for (part, tx) in sends {
            let n = (part.len() / self.dim) as u64;
            match tx.try_send(part) {
                Ok(()) => {
                    self.counters.ingested.fetch_add(n, Ordering::Relaxed);
                    accepted += n;
                }
                // Full queue — or a worker that raced us into shutdown and
                // hung up — both shed: at-most-once transport, and the
                // tally the client sees stays consistent with the
                // counters.
                Err(mpsc::TrySendError::Full(_))
                | Err(mpsc::TrySendError::Disconnected(_)) => {
                    self.counters.ingest_shed.fetch_add(n, Ordering::Relaxed);
                    shed += n;
                }
            }
        }
        Ok((accepted, shed))
    }

    /// Counters + shape, for the `Stats` query.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            version: self.version(),
            kappa: self.kappa,
            dim: self.dim,
            workers: self.workers_per_shard * self.shards.len(),
            shards: self.shards.len(),
            probe_n: self.probe_n,
            merges: self.counters.merges.load(Ordering::Relaxed),
            ingested: self.counters.ingested.load(Ordering::Relaxed),
            ingest_shed: self.counters.ingest_shed.load(Ordering::Relaxed),
            queries: self.counters.queries.load(Ordering::Relaxed),
            shard_versions: self.shard_versions(),
            shard_merges: self
                .shards
                .iter()
                .map(|s| s.merges.load(Ordering::Relaxed))
                .collect(),
            state_dir: self
                .state_dir
                .as_ref()
                .map(|d| d.display().to_string()),
            last_checkpoint: self.last_checkpoint(),
        }
    }

    /// Stop every shard fleet: flag the workers, let them drain and flush,
    /// close the queues, join the reducers. Each shard's final shared
    /// version is published before return, so a post-shutdown `snapshot()`
    /// is complete.
    ///
    /// Takes `&self` so the service can stay shared with open connections;
    /// those keep answering queries from the last epochs. Calling it twice
    /// is an error.
    pub fn shutdown(&self) -> Result<ServeOutcome> {
        let mut fleets = Vec::with_capacity(self.shards.len());
        for (s, shard) in self.shards.iter().enumerate() {
            let fleet = shard
                .fleet
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
                .ok_or_else(|| anyhow!("service already shut down"))?;
            fleets.push((s, fleet));
        }
        self.stop.store(true, Ordering::Release);
        self.go.store(true, Ordering::Release); // release any paused workers
        // Disconnect ingest so worker drains see closed channels.
        for shard in &self.shards {
            shard.ingest_txs.lock().unwrap_or_else(|e| e.into_inner()).clear();
        }
        let mut workers = Vec::new();
        let mut shard_outcomes = Vec::with_capacity(fleets.len());
        let mut total_merges = 0u64;
        let mut global_flat = Vec::with_capacity(self.kappa * self.dim);
        for (s, fleet) in fleets {
            for j in fleet.workers {
                workers.push(j.join().map_err(|_| anyhow!("serve worker panicked"))??);
            }
            // Shard workers done: drop the template handle so its reducer
            // drains (worker-held clones are gone once the joins return).
            drop(fleet.queue_template);
            let (merges, final_shared) = fleet
                .reducer
                .join()
                .map_err(|_| anyhow!("serve reducer panicked"))??;
            total_merges += merges;
            global_flat.extend_from_slice(final_shared.flat());
            shard_outcomes.push(ShardOutcome { shard: s, merges, final_shared });
        }
        // Fleets quiesced and final epochs published: drain the
        // checkpointer so the state dir carries everything that was
        // learned (its final pass sees the post-join versions).
        if let Some(ck) = self
            .checkpointer
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            ck.stop()?;
        }
        Ok(ServeOutcome {
            workers,
            merges: total_merges,
            final_shared: Codebook::from_flat(self.kappa, self.dim, global_flat),
            shards: shard_outcomes,
        })
    }
}

/// Load durable state for a warm start and validate it against the
/// deployment config. `Ok(None)` = cold start (no manifest yet). Any
/// shape mismatch — shard count, total kappa, dim — is a hard error:
/// seeding a fleet from a codebook of the wrong shape would corrupt it
/// silently, and retraining over state the operator asked us to keep
/// would be data loss.
fn load_restore(
    dir: &Path,
    cfg: &ExperimentConfig,
    serve: &ServeConfig,
) -> Result<Option<RestoredState>> {
    // The serving startup owns the state dir: sweep stale `.tmp` files
    // from interrupted checkpoints before loading. (The shared loader
    // itself never removes anything — `dalvq state inspect` reads
    // through it against possibly-live directories.)
    persist::sweep_tmp(dir);
    let Some(state) = persist::load_state(dir)
        .with_context(|| format!("restoring state from {}", dir.display()))?
    else {
        return Ok(None);
    };
    let m = &state.manifest;
    if m.shards != serve.shards || m.kappa != cfg.vq.kappa || m.dim != cfg.dim() {
        return Err(anyhow!(
            "state dir {} was written by a deployment with shards={} \
             kappa={} dim={}, but this config has shards={} kappa={} dim={}; \
             pass a matching config or a fresh --state-dir",
            dir.display(),
            m.shards,
            m.kappa,
            m.dim,
            serve.shards,
            cfg.vq.kappa,
            cfg.dim()
        ));
    }
    // The saved RNG cursors are only exact when the exchange window is
    // unchanged (each fold = points_per_exchange points); a silent
    // mismatch would resume every schedule at the wrong position.
    if m.points_per_exchange != serve.points_per_exchange {
        return Err(anyhow!(
            "state dir {} was checkpointed at points_per_exchange = {}, but \
             this config uses {}; the saved schedule cursors would be \
             misinterpreted — keep the window or start a fresh --state-dir",
            dir.display(),
            m.points_per_exchange,
            serve.points_per_exchange
        ));
    }
    Ok(Some(state))
}

/// Cold-start bootstrap of a state directory: router + every shard's
/// initial state + manifest, so the directory is restorable before the
/// first fold (a service killed seconds after start must still warm-
/// restart cleanly).
fn write_initial_state(
    dir: &Path,
    router: &Router,
    shards: &[ShardFleet],
    cfg: &ExperimentConfig,
    serve: &ServeConfig,
) -> Result<()> {
    let router_state = RouterState { centroids: router.centroids().clone() };
    persist::write_atomic(dir, persist::ROUTER_FILE, &router_state.encode())?;
    let mut versions = Vec::with_capacity(shards.len());
    for (s, fleet) in shards.iter().enumerate() {
        let snap = fleet.store.load();
        let state = ShardState {
            shard: s as u32,
            version: snap.version,
            merges: fleet.merges.load(Ordering::Relaxed),
            rng_cursor: snap.version * serve.points_per_exchange as u64,
            codebook: snap.codebook.clone(),
        };
        persist::write_atomic(dir, &persist::shard_file(s), &state.encode())?;
        versions.push(snap.version);
    }
    Manifest {
        format: persist::FORMAT,
        shards: shards.len(),
        kappa: cfg.vq.kappa,
        dim: cfg.dim(),
        points_per_exchange: serve.points_per_exchange,
        shard_versions: versions,
    }
    .save(dir)
}

/// Pad a shard's bootstrap region up to `min_pts` points: cycle the
/// region's own points, or fall back to the dataset prefix for an empty
/// cell (possible only in pathological router fits).
fn ensure_min_points(
    mut part: Vec<f32>,
    dim: usize,
    min_pts: usize,
    fallback: &[f32],
) -> Vec<f32> {
    if part.is_empty() {
        let take = min_pts.min(fallback.len() / dim);
        part.extend_from_slice(&fallback[..take * dim]);
    }
    let have = part.len() / dim;
    let mut i = 0usize;
    while part.len() / dim < min_pts {
        let s = i % have;
        part.extend_from_within(s * dim..(s + 1) * dim);
        i += 1;
    }
    part
}

/// The serving reducer: the cloud reducer's fold-and-put loop plus epoch
/// publication for the read path. One per shard. `initial_merges` seeds
/// the fold clock on a warm restart, so published versions continue the
/// saved sequence instead of restarting at 1.
#[allow(clippy::too_many_arguments)]
fn run_serving_reducer(
    rx: mpsc::Receiver<DeltaMsg>,
    mut blob: BlobHandle,
    store: Arc<SnapshotStore>,
    counters: Arc<ServeCounters>,
    shard_merges: Arc<AtomicU64>,
    w0: Codebook,
    publish_every: u64,
    initial_merges: u64,
) -> Result<(u64, Codebook)> {
    let mut w_srd = w0;
    let mut merges: u64 = initial_merges;
    for msg in rx.iter() {
        w_srd.apply_delta(&msg.delta);
        merges += 1;
        shard_merges.store(merges, Ordering::Relaxed);
        counters.merges.fetch_add(1, Ordering::Relaxed);
        blob.put(w_srd.clone(), merges)?;
        if merges % publish_every == 0 {
            store.publish(w_srd.clone(), merges);
        }
    }
    // Queue closed: one final epoch so readers see everything folded.
    store.publish(w_srd.clone(), merges);
    Ok((merges, w_srd))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchemeConfig;
    use crate::sim::DelayModel;
    use crate::vq::Schedule;

    pub(crate) fn tiny_cfg(m: usize) -> (ExperimentConfig, ServeConfig) {
        let mut cfg = ExperimentConfig::default();
        cfg.m = m;
        cfg.data.mixture.components = 4;
        cfg.data.mixture.dim = 2;
        cfg.data.n_total = 2_000;
        cfg.data.eval_points = 256;
        cfg.vq.kappa = 4;
        cfg.vq.schedule = Schedule::Constant { eps0: 0.01 };
        cfg.scheme = SchemeConfig::AsyncDelta {
            tau: 10,
            up_delay: DelayModel::Instant,
            down_delay: DelayModel::Instant,
        };
        let mut serve = ServeConfig::default();
        serve.points_per_exchange = 50;
        // pace gently so the test fleet doesn't saturate small CI hosts
        serve.point_compute = 2e-6;
        (cfg, serve)
    }

    #[test]
    fn service_trains_while_serving_and_shuts_down_cleanly() {
        let (cfg, serve) = tiny_cfg(2);
        let svc = VqService::start(&cfg, &serve).unwrap();
        let v0 = svc.version();
        let eval = cfg.data.mixture.eval_sample(256, cfg.seed);
        let (_, c0) = svc.query_distortion(&eval);
        // wait for some folds to land
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while svc.version() < v0 + 5 {
            assert!(
                std::time::Instant::now() < deadline,
                "no folds published within 10s"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let snap = svc.snapshot();
        assert!(snap.version >= v0 + 5);
        assert!(snap.codebook.is_finite());
        // constant-step training on the same mixture must not blow up C
        let (_, c1) = svc.query_distortion(&eval);
        assert!(c1 < c0 * 2.0 + 1.0, "{c0} -> {c1}");
        let out = svc.shutdown().unwrap();
        assert!(out.merges >= 5);
        assert!(out.final_shared.is_finite());
        let trained: u64 = out.workers.iter().map(|w| w.points_trained).sum();
        assert!(trained > 0);
    }

    #[test]
    fn ingest_validates_shape_and_counts() {
        let (cfg, serve) = tiny_cfg(1);
        let svc = VqService::start(&cfg, &serve).unwrap();
        assert!(svc.ingest(&[1.0, 2.0, 3.0]).is_err()); // dim = 2
        let (acc, shed) = svc.ingest(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(acc + shed, 2);
        assert_eq!(svc.ingest(&[]).unwrap(), (0, 0));
        let stats = svc.stats();
        assert_eq!(stats.ingested + stats.ingest_shed, 2);
        assert_eq!(stats.workers, 1);
        assert_eq!(stats.shards, 1);
        assert_eq!(stats.dim, 2);
        svc.shutdown().unwrap();
    }

    #[test]
    fn sharded_service_routes_queries_and_ingest() {
        let (mut cfg, mut serve) = tiny_cfg(1);
        cfg.vq.kappa = 8; // 2 prototypes per shard
        serve.shards = 4;
        serve.probe_n = 2;
        let svc = VqService::start(&cfg, &serve).unwrap();
        assert_eq!(svc.shards(), 4);
        assert_eq!(svc.router().shards(), 4);

        let eval = cfg.data.mixture.eval_sample(128, cfg.seed);
        let (_, codes, dists) = svc.query_nearest(&eval);
        assert_eq!(codes.len(), 128);
        // global codes span the whole kappa range, not one shard's
        assert!(codes.iter().all(|&c| (c as usize) < 8));
        assert!(dists.iter().all(|d| d.is_finite() && *d >= 0.0));

        // ingest fans out across shards without error
        let (acc, shed) = svc.ingest(&eval).unwrap();
        assert_eq!(acc + shed, 128);

        let stats = svc.stats();
        assert_eq!(stats.shards, 4);
        assert_eq!(stats.probe_n, 2);
        assert_eq!(stats.shard_versions.len(), 4);
        assert_eq!(stats.shard_merges.len(), 4);
        assert_eq!(stats.kappa, 8);

        // Quiesce before cross-probe comparisons: reads must come from
        // the identical (now frozen) epochs, not two loads of a moving
        // target. The read path stays up after shutdown by design.
        let out = svc.shutdown().unwrap();
        assert_eq!(out.shards.len(), 4);
        assert_eq!(out.final_shared.kappa(), 8);

        // exhaustive probe can only improve (or equal) every distance
        let (_, _, routed) = svc.query_nearest_probed(&eval, 2);
        let (_, _, oracle) = svc.query_nearest_probed(&eval, 4);
        for (d2, dfull) in routed.iter().zip(&oracle) {
            assert!(dfull <= d2, "oracle worse than probe: {dfull} > {d2}");
        }

        // the merged snapshot concatenates shard codebooks in code order
        let snap = svc.snapshot();
        assert_eq!(snap.codebook.kappa(), 8);
        for (s, shard_snap) in svc.snapshots().iter().enumerate() {
            assert_eq!(
                &snap.codebook.flat()[s * 2 * 2..(s + 1) * 2 * 2],
                shard_snap.codebook.flat()
            );
        }
    }

    #[test]
    fn ensure_min_points_pads_and_falls_back() {
        let fallback: Vec<f32> = (0..12).map(|i| i as f32).collect(); // 6 pts dim 2
        // enough points: untouched
        let p = ensure_min_points(vec![1.0, 2.0, 3.0, 4.0], 2, 2, &fallback);
        assert_eq!(p, vec![1.0, 2.0, 3.0, 4.0]);
        // short: cycle-padded from its own points
        let p = ensure_min_points(vec![1.0, 2.0], 2, 3, &fallback);
        assert_eq!(p, vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
        // empty: seeded from the fallback prefix
        let p = ensure_min_points(Vec::new(), 2, 2, &fallback);
        assert_eq!(p, vec![0.0, 1.0, 2.0, 3.0]);
    }
}
