//! The in-process service: a serving fleet (workers + queue + blob +
//! reducer) glued to a [`SnapshotStore`] read path.
//!
//! Training topology is exactly the cloud runtime's (eq. 9 / CloudDALVQ):
//! `M` worker threads exchange displacements through the queue and blob
//! services without barriers, and a dedicated reducer folds whatever
//! arrives next. The one addition is the *publication* step: every
//! `publish_every` folds the reducer epoch-swaps an immutable snapshot
//! into the store, which is where every query is answered — so reads never
//! contend with training beyond an `Arc` clone.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Barrier, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::cloud::{
    BlobHandle, BlobService, DeltaMsg, LatencyInjector, QueueService,
};
use crate::config::{ExperimentConfig, ServeConfig};
use crate::vq::{init_codebook, Codebook};

use super::snapshot::{Snapshot, SnapshotStore};
use super::worker::{run_serve_worker, ServeWorkerOutcome, ServeWorkerParams};

/// Live counters, shared between the fleet and the front-end.
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Ingested points accepted into worker queues.
    pub ingested: AtomicU64,
    /// Ingested points shed because a worker's queue was full.
    pub ingest_shed: AtomicU64,
    /// Queries answered (all read ops; maintained by the front-end).
    pub queries: AtomicU64,
    /// Deltas folded by the reducer (may run ahead of the published
    /// snapshot version when `publish_every > 1`).
    pub merges: AtomicU64,
}

/// A point-in-time view of [`ServeCounters`] plus service shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeStats {
    pub version: u64,
    pub kappa: usize,
    pub dim: usize,
    pub workers: usize,
    /// Reducer folds to date (>= version; they differ when the reducer
    /// publishes every `publish_every` folds).
    pub merges: u64,
    pub ingested: u64,
    pub ingest_shed: u64,
    pub queries: u64,
}

/// What the fleet reports at shutdown.
#[derive(Debug)]
pub struct ServeOutcome {
    pub workers: Vec<ServeWorkerOutcome>,
    /// Deltas folded by the reducer over the service's lifetime.
    pub merges: u64,
    pub final_shared: Codebook,
}

/// The training fleet's join handles — taken exactly once at shutdown.
struct Fleet {
    workers: Vec<JoinHandle<Result<ServeWorkerOutcome>>>,
    reducer: JoinHandle<Result<(u64, Codebook)>>,
    /// Held so the queue stays open until shutdown drops it.
    queue_template: crate::cloud::QueueHandle,
}

/// The running service. Queries go through [`VqService::snapshot`];
/// ingestion through [`VqService::ingest`]; the TCP front-end
/// ([`super::Server`]) is a thin adapter over exactly these methods.
///
/// Shutdown takes `&self` (the service is normally shared behind an
/// `Arc` with connection handlers), so callers never need to reclaim
/// unique ownership from in-flight connections.
pub struct VqService {
    store: Arc<SnapshotStore>,
    counters: Arc<ServeCounters>,
    dim: usize,
    kappa: usize,
    workers_n: usize,
    /// Cloned under a short lock per ingest call; cleared at shutdown.
    ingest_txs: Mutex<Vec<mpsc::SyncSender<Vec<f32>>>>,
    ingest_cursor: AtomicUsize,
    stop: Arc<AtomicBool>,
    fleet: Mutex<Option<Fleet>>,
}

impl VqService {
    /// Build the fleet and start serving. Blocks until every worker has
    /// built its engine and passed the ready barrier, so the first query
    /// already sees a live system.
    pub fn start(cfg: &ExperimentConfig, serve: &ServeConfig) -> Result<VqService> {
        cfg.validate()?;
        serve.validate(cfg)?;

        let dataset = cfg.data.mixture.dataset(cfg.data.n_total, cfg.seed);
        let shards = dataset.split(cfg.m);
        let w0 = init_codebook(
            cfg.vq.init,
            cfg.vq.kappa,
            cfg.dim(),
            dataset.flat(),
            cfg.seed,
        );

        let store = SnapshotStore::new(w0.clone());
        let counters = Arc::new(ServeCounters::default());
        let blob = BlobService::spawn(w0.clone());
        let (queue, queue_rx) = QueueService::create(1024);
        let stop = Arc::new(AtomicBool::new(false));
        let ready = Arc::new(Barrier::new(cfg.m + 1));

        // Reducer: fold deltas, refresh the blob for workers, publish
        // epochs for readers.
        let reducer = {
            let blob = blob.clone();
            let store = Arc::clone(&store);
            let counters = Arc::clone(&counters);
            let w0 = w0.clone();
            let publish_every = serve.publish_every;
            std::thread::Builder::new()
                .name("dalvq-serve-reducer".into())
                .spawn(move || {
                    run_serving_reducer(
                        queue_rx, blob, store, counters, w0, publish_every,
                    )
                })
                .expect("spawning serve reducer thread")
        };

        let mut ingest_txs = Vec::with_capacity(cfg.m);
        let mut workers = Vec::with_capacity(cfg.m);
        for (i, shard) in shards.into_iter().enumerate() {
            let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(serve.ingest_queue);
            ingest_txs.push(tx);
            let params = ServeWorkerParams {
                worker_id: i,
                shard,
                w0: w0.clone(),
                schedule: cfg.vq.schedule,
                tau: cfg.scheme.tau(),
                points_per_exchange: serve.points_per_exchange,
                point_compute: serve.point_compute,
                absorb_per_chunk: serve.absorb_per_chunk,
                engine_spec: cfg.engine.clone(),
                ready: Arc::clone(&ready),
                stop: Arc::clone(&stop),
            };
            let q = queue.clone().with_latency(LatencyInjector::new(
                serve.service_latency,
                serve.latency_jitter,
                serve.drop_prob,
                cfg.seed ^ ((i as u64) << 8),
            ));
            let b = blob.clone().with_latency(LatencyInjector::new(
                serve.service_latency,
                serve.latency_jitter,
                0.0, // downloads are request/response; loss shows as latency
                cfg.seed ^ ((i as u64) << 8) ^ 1,
            ));
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dalvq-serve-worker-{i}"))
                    .spawn(move || run_serve_worker(params, rx, q, b))
                    .expect("spawning serve worker thread"),
            );
        }
        ready.wait(); // engines built; the service is live

        Ok(VqService {
            store,
            counters,
            dim: cfg.dim(),
            kappa: cfg.vq.kappa,
            workers_n: cfg.m,
            ingest_txs: Mutex::new(ingest_txs),
            ingest_cursor: AtomicUsize::new(0),
            stop,
            fleet: Mutex::new(Some(Fleet {
                workers,
                reducer,
                queue_template: queue,
            })),
        })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn kappa(&self) -> usize {
        self.kappa
    }

    /// Current published epoch — the basis of every query answer.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        self.store.load()
    }

    /// Version of the current epoch (lock-free; freshness polling).
    pub fn version(&self) -> u64 {
        self.store.version()
    }

    pub fn counters(&self) -> &Arc<ServeCounters> {
        &self.counters
    }

    /// Feed points into the training stream. Batches are sharded
    /// round-robin across workers; a full worker queue sheds its batch
    /// (at-most-once ingestion — the stochastic algorithm tolerates loss,
    /// and blocking here would couple ingest pressure to query latency).
    /// Returns `(accepted, shed)` point counts.
    pub fn ingest(&self, points: &[f32]) -> Result<(u64, u64)> {
        if points.is_empty() {
            return Ok((0, 0));
        }
        if points.len() % self.dim != 0 {
            return Err(anyhow!(
                "ingest batch of {} floats is not a multiple of dim {}",
                points.len(),
                self.dim
            ));
        }
        let n = (points.len() / self.dim) as u64;
        let tx = {
            let txs = self.ingest_txs.lock().unwrap_or_else(|e| e.into_inner());
            if txs.is_empty() {
                return Err(anyhow!("service is shutting down"));
            }
            let i = self.ingest_cursor.fetch_add(1, Ordering::Relaxed) % txs.len();
            txs[i].clone()
        };
        match tx.try_send(points.to_vec()) {
            Ok(()) => {
                self.counters.ingested.fetch_add(n, Ordering::Relaxed);
                Ok((n, 0))
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.counters.ingest_shed.fetch_add(n, Ordering::Relaxed);
                Ok((0, n))
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(anyhow!("service is shutting down"))
            }
        }
    }

    /// Counters + shape, for the `Stats` query.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            version: self.version(),
            kappa: self.kappa,
            dim: self.dim,
            workers: self.workers_n,
            merges: self.counters.merges.load(Ordering::Relaxed),
            ingested: self.counters.ingested.load(Ordering::Relaxed),
            ingest_shed: self.counters.ingest_shed.load(Ordering::Relaxed),
            queries: self.counters.queries.load(Ordering::Relaxed),
        }
    }

    /// Stop the fleet: flag the workers, let them drain and flush, close
    /// the queue, join the reducer. The final shared version is published
    /// before return, so a post-shutdown `snapshot()` is complete.
    ///
    /// Takes `&self` so the service can stay shared with open connections;
    /// those keep answering queries from the last epoch. Calling it twice
    /// is an error.
    pub fn shutdown(&self) -> Result<ServeOutcome> {
        let fleet = self
            .fleet
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .ok_or_else(|| anyhow!("service already shut down"))?;
        self.stop.store(true, Ordering::Release);
        // Disconnect ingest so worker drains see closed channels.
        self.ingest_txs.lock().unwrap_or_else(|e| e.into_inner()).clear();
        let mut outcomes = Vec::with_capacity(fleet.workers.len());
        for j in fleet.workers {
            outcomes.push(j.join().map_err(|_| anyhow!("serve worker panicked"))??);
        }
        // All workers done: drop the template handle so the reducer drains.
        drop(fleet.queue_template);
        let (merges, final_shared) = fleet
            .reducer
            .join()
            .map_err(|_| anyhow!("serve reducer panicked"))??;
        Ok(ServeOutcome { workers: outcomes, merges, final_shared })
    }
}

/// The serving reducer: the cloud reducer's fold-and-put loop plus epoch
/// publication for the read path.
fn run_serving_reducer(
    rx: mpsc::Receiver<DeltaMsg>,
    mut blob: BlobHandle,
    store: Arc<SnapshotStore>,
    counters: Arc<ServeCounters>,
    w0: Codebook,
    publish_every: u64,
) -> Result<(u64, Codebook)> {
    let mut w_srd = w0;
    let mut merges: u64 = 0;
    for msg in rx.iter() {
        w_srd.apply_delta(&msg.delta);
        merges += 1;
        counters.merges.store(merges, Ordering::Relaxed);
        blob.put(w_srd.clone(), merges)?;
        if merges % publish_every == 0 {
            store.publish(w_srd.clone(), merges);
        }
    }
    // Queue closed: one final epoch so readers see everything folded.
    store.publish(w_srd.clone(), merges);
    Ok((merges, w_srd))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchemeConfig;
    use crate::sim::DelayModel;
    use crate::vq::Schedule;

    pub(crate) fn tiny_cfg(m: usize) -> (ExperimentConfig, ServeConfig) {
        let mut cfg = ExperimentConfig::default();
        cfg.m = m;
        cfg.data.mixture.components = 4;
        cfg.data.mixture.dim = 2;
        cfg.data.n_total = 2_000;
        cfg.data.eval_points = 256;
        cfg.vq.kappa = 4;
        cfg.vq.schedule = Schedule::Constant { eps0: 0.01 };
        cfg.scheme = SchemeConfig::AsyncDelta {
            tau: 10,
            up_delay: DelayModel::Instant,
            down_delay: DelayModel::Instant,
        };
        let mut serve = ServeConfig::default();
        serve.points_per_exchange = 50;
        // pace gently so the test fleet doesn't saturate small CI hosts
        serve.point_compute = 2e-6;
        (cfg, serve)
    }

    #[test]
    fn service_trains_while_serving_and_shuts_down_cleanly() {
        let (cfg, serve) = tiny_cfg(2);
        let svc = VqService::start(&cfg, &serve).unwrap();
        let v0 = svc.version();
        let eval = cfg.data.mixture.eval_sample(256, cfg.seed);
        let c0 = svc.snapshot().distortion(&eval);
        // wait for some folds to land
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while svc.version() < v0 + 5 {
            assert!(
                std::time::Instant::now() < deadline,
                "no folds published within 10s"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let snap = svc.snapshot();
        assert!(snap.version >= v0 + 5);
        assert!(snap.codebook.is_finite());
        // constant-step training on the same mixture must not blow up C
        let c1 = snap.distortion(&eval);
        assert!(c1 < c0 * 2.0 + 1.0, "{c0} -> {c1}");
        let out = svc.shutdown().unwrap();
        assert!(out.merges >= 5);
        assert!(out.final_shared.is_finite());
        let trained: u64 = out.workers.iter().map(|w| w.points_trained).sum();
        assert!(trained > 0);
    }

    #[test]
    fn ingest_validates_shape_and_counts() {
        let (cfg, serve) = tiny_cfg(1);
        let svc = VqService::start(&cfg, &serve).unwrap();
        assert!(svc.ingest(&[1.0, 2.0, 3.0]).is_err()); // dim = 2
        let (acc, shed) = svc.ingest(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(acc + shed, 2);
        assert_eq!(svc.ingest(&[]).unwrap(), (0, 0));
        let stats = svc.stats();
        assert_eq!(stats.ingested + stats.ingest_shed, 2);
        assert_eq!(stats.workers, 1);
        assert_eq!(stats.dim, 2);
        svc.shutdown().unwrap();
    }
}
