//! Paper-figure presets — the exact experiment grid of the evaluation.
//!
//! | preset | paper artifact | scheme | M sweep | comms |
//! |--------|----------------|--------|---------|-------|
//! | [`fig1`] | Figure 1 | averaging (eq. 3), τ=10 | 1, 2, 10 | instantaneous |
//! | [`fig2`] | Figure 2 | delta sync (eq. 8), τ=10 | 1, 2, 10 | instantaneous |
//! | [`fig3`] | Figure 3 | async delta (eq. 9), τ=10 | 1, 2, 10 | geometric delays |
//! | [`fig4`] | Figure 4 | async delta on the cloud runtime | 1…32 | latency-injected services |
//! | [`ablation_tau`] | §3 remark | delta sync, τ swept | 10 | instantaneous |
//! | [`ablation_delay`] | §4 remark | async delta, delay swept | 10 | geometric |

use crate::sim::DelayModel;

use super::{
    CloudConfig, ExperimentConfig, FigureConfig, SchemeConfig, ServeConfig,
};

/// The paper's `M` grid for the simulated figures.
pub const PAPER_MS: [usize; 3] = [1, 2, 10];

/// Figure 1 — scheme (3): averaging brings no speed-up.
pub fn fig1() -> FigureConfig {
    let mut base = ExperimentConfig::default();
    base.scheme = SchemeConfig::Averaging { tau: 10 };
    // "a simulated parallel implementation in which communications are
    // instantaneous" — merge and broadcast cost nothing.
    base.cost.merge_cost = 0.0;
    base.cost.broadcast_cost = 0.0;
    // Paper setting: "starting from a random initial w(0)" — NOT drawn
    // from the data (a data-drawn codebook starts nearly converged and
    // compresses every wall-clock difference the figures are about).
    base.vq.init = crate::vq::InitMethod::Gaussian;
    // Overlapping, imbalanced mixture: convergence stays schedule-limited
    // over the whole run, like the paper's curves.
    base.data.mixture.std = 1.2;
    base.data.mixture.noise_frac = 0.05;
    base.data.mixture.imbalance = 0.5;
    // Slow schedule: the run stays transport-limited (prototypes still
    // moving at the end for M = 1), which is the regime where the paper's
    // wall-clock comparisons live.
    base.vq.schedule =
        crate::vq::Schedule::InverseTime { eps0: 0.005, half_life: 50_000.0 };
    FigureConfig {
        id: "fig1".into(),
        title: "Performance curves for iterations (3) with tau = 10 and \
                M = 1, 2, 10 (averaging scheme)"
            .into(),
        base,
        ms: PAPER_MS.to_vec(),
        cloud: None,
    }
}

/// Figure 2 — scheme (8): delta merge obtains the expected speed-ups.
pub fn fig2() -> FigureConfig {
    let mut fig = fig1();
    fig.id = "fig2".into();
    fig.title = "Performance curves for iterations (8) with tau = 10 and \
                 M = 1, 2, 10 (delta-merge scheme)"
        .into();
    fig.base.scheme = SchemeConfig::DeltaSync { tau: 10 };
    fig
}

/// Figure 3 — scheme (9): asynchronous delta merge with geometric delays.
///
/// Delay scale: one chunk of τ=10 points costs 1e-4 s of virtual compute;
/// a mean one-way delay of 2e-4 s (two chunks) is the paper's “small
/// delays” regime.
pub fn fig3() -> FigureConfig {
    let mut fig = fig1();
    fig.id = "fig3".into();
    fig.title = "Performance curves for iterations (9) with tau = 10 and \
                 M = 1, 2, 10 (asynchronous scheme, geometric delays)"
        .into();
    fig.base.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Geometric { p: 0.5, unit: 1e-4 },
        down_delay: DelayModel::Geometric { p: 0.5, unit: 1e-4 },
    };
    fig
}

/// Figure 4 — the cloud implementation, scaling to 32 processing units.
///
/// Real thread-per-worker concurrency against latency-injected blob/queue
/// services (the Azure substitution of DESIGN.md). Runs shorter per-worker
/// streams than the simulator figures because this one burns real wall
/// time.
pub fn fig4() -> FigureConfig {
    let mut base = ExperimentConfig::default();
    base.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant, // delays come from the services
        down_delay: DelayModel::Instant,
    };
    base.run.points_per_worker = 100_000;
    base.run.eval_interval = 0.02;
    // At M = 32 the staleness window is ~650 points (exchange window plus
    // latency x pacing); keep M*window*eps/kappa well below 1
    // (see Schedule::paper_default).
    // eps0 = 2e-4 leaves ~4x margin below the envelope so that transient
    // host-load spikes (which stretch real latencies and hence staleness —
    // the paper's straggler phenomenon) cannot destabilize the run.
    base.vq.schedule =
        crate::vq::Schedule::InverseTime { eps0: 2e-4, half_life: 40_000.0 };
    let mut cloud = CloudConfig::default();
    // Exchange every 500 points: at 32 workers the reducer folds ~6/ms,
    // well inside one core's budget, so queue backlog (which would grow
    // the staleness window unboundedly) cannot build up.
    cloud.points_per_exchange = 500;
    FigureConfig {
        id: "fig4".into(),
        title: "Performance curves for iterations (9) on the cloud \
                implementation, M up to 32"
            .into(),
        base,
        ms: vec![1, 2, 4, 8, 16, 32],
        cloud: Some(cloud),
    }
}

/// ABL-τ — “the acceleration is greater when the reducing phase is
/// frequent” (§3): delta sync at M = 10 with τ swept.
pub fn ablation_tau() -> Vec<FigureConfig> {
    // spans stable (tau <= 200), degraded (1000) and unstable (2000)
    // regions of the M*tau*eps/kappa envelope
    [1usize, 10, 50, 200, 1000, 2000]
        .iter()
        .map(|&tau| {
            let mut fig = fig2();
            fig.id = format!("abl_tau_{tau}");
            fig.title = format!("Delta-merge scheme at M = 10, tau = {tau}");
            fig.base.scheme = SchemeConfig::DeltaSync { tau };
            fig.ms = vec![10];
            fig
        })
        .collect()
}

/// ABL-delay — “small delays … only slightly impacts performances” (§4):
/// async delta at M = 10 with the mean delay swept.
pub fn ablation_delay() -> Vec<FigureConfig> {
    // mean one-way delays in chunk-compute units (1 chunk = 1e-4 s)
    [0.0f64, 2e-4, 1e-3, 5e-3]
        .iter()
        .map(|&mean| {
            let mut fig = fig3();
            fig.id = format!("abl_delay_{}", (mean * 1e4) as u64);
            fig.title = format!(
                "Asynchronous scheme at M = 10, mean one-way delay {mean} s"
            );
            let delay = if mean == 0.0 {
                DelayModel::Instant
            } else {
                DelayModel::Geometric { p: 0.5, unit: mean * 0.5 }
            };
            fig.base.scheme = SchemeConfig::AsyncDelta {
                tau: 10,
                up_delay: delay,
                down_delay: delay,
            };
            fig.ms = vec![10];
            fig
        })
        .collect()
}

/// A serving deployment: base experiment + service parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServePreset {
    pub base: ExperimentConfig,
    pub serve: ServeConfig,
}

impl ServePreset {
    pub fn validate(&self) -> crate::Result<()> {
        self.base.validate()?;
        self.serve.validate(&self.base)
    }
}

/// The `serve` preset: a 4-worker fleet on the native engine, constant
/// learning rate (a *serving* codebook must keep tracking drift — a
/// decaying schedule would freeze it), gentle pacing so the training fleet
/// leaves CPU for the query path on small hosts.
pub fn serve() -> ServePreset {
    let mut base = ExperimentConfig::default();
    base.m = 4;
    base.data.mixture.components = 8;
    base.data.mixture.dim = 4;
    base.data.n_total = 16_000;
    base.data.eval_points = 1_024;
    base.vq.kappa = 8;
    // Constant step: the fleet applies ~M*window*eps/kappa displacement
    // per exchange; 0.01 stays well inside the stability envelope at M=4,
    // window=100, kappa=8 while still tracking ingest drift in seconds.
    base.vq.schedule = crate::vq::Schedule::Constant { eps0: 0.01 };
    base.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant, // latency comes from ServeConfig
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.points_per_exchange = 100;
    serve.point_compute = 2e-6; // ~500k pts/s/worker cap
    ServePreset { base, serve }
}

/// The `serve` preset partitioned across `shards` codebook shards: each
/// shard runs its own independent fleet over `kappa / shards` prototypes,
/// queries multi-probe the 2 nearest shards (1 when there is only one).
/// `shards` must divide the preset's `kappa` (8).
pub fn serve_sharded(shards: usize) -> ServePreset {
    let mut p = serve();
    p.serve.shards = shards;
    p.serve.probe_n = 2.min(shards.max(1));
    p
}

/// The `serve` preset with durable state: checkpoints every shard into
/// `state_dir` every `checkpoint_every` folds, and a restart pointed at
/// the same directory resumes at the saved shard versions instead of
/// retraining. This is what `dalvq serve --state-dir` runs.
pub fn serve_durable(state_dir: impl Into<std::path::PathBuf>) -> ServePreset {
    let mut p = serve();
    p.serve.state_dir = Some(state_dir.into());
    p
}

/// The sharded durable preset with the auto-rebalance monitor armed:
/// checkpoints into `state_dir` and re-partitions the shards online
/// whenever max/mean per-shard ingest exceeds `skew`. This is what
/// `dalvq serve --shards S --state-dir DIR --rebalance-skew R` runs.
pub fn serve_rebalancing(
    shards: usize,
    state_dir: impl Into<std::path::PathBuf>,
    skew: f64,
) -> ServePreset {
    let mut p = serve_sharded(shards);
    p.serve.state_dir = Some(state_dir.into());
    p.serve.rebalance_skew = skew;
    p.serve.rebalance_min_folds = 32;
    p
}

/// A read-only follower of the leader at `leader`: restores — and keeps
/// re-syncing — from the leader's shipped checkpoints, serving the full
/// read surface and answering writes with `NotLeader`. The serving
/// topology (shards, kappa, dim) is adopted from the leader's manifest.
/// This is what `dalvq serve --follow ADDR` runs. The probe width
/// defaults to 2 (clamped to the leader's shard count at adoption).
pub fn serve_follower(leader: impl Into<String>) -> ServePreset {
    let mut p = serve();
    p.serve.follow = Some(leader.into());
    p.serve.probe_n = 2;
    p
}

/// Quickstart: tiny 2-D problem on the PJRT engine (the `k8d2` artifacts).
pub fn quickstart() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.data.mixture.components = 8;
    cfg.data.mixture.dim = 2;
    cfg.data.n_total = 8_000;
    cfg.data.eval_points = 1_024;
    cfg.vq.kappa = 8;
    cfg.m = 4;
    cfg.run.points_per_worker = 20_000;
    cfg.run.eval_interval = 0.005;
    cfg.engine = crate::runtime::EngineSpec::pjrt_default("k8d2");
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figure_presets_validate() {
        for fig in [fig1(), fig2(), fig3(), fig4()] {
            fig.validate().unwrap_or_else(|e| panic!("{}: {e}", fig.id));
        }
        for fig in ablation_tau().into_iter().chain(ablation_delay()) {
            fig.validate().unwrap_or_else(|e| panic!("{}: {e}", fig.id));
        }
    }

    #[test]
    fn fig1_uses_averaging_fig2_delta() {
        assert!(matches!(fig1().base.scheme, SchemeConfig::Averaging { tau: 10 }));
        assert!(matches!(fig2().base.scheme, SchemeConfig::DeltaSync { tau: 10 }));
        assert!(matches!(fig3().base.scheme, SchemeConfig::AsyncDelta { .. }));
    }

    #[test]
    fn fig4_scales_to_32() {
        let f = fig4();
        assert_eq!(*f.ms.last().unwrap(), 32);
        assert!(f.cloud.is_some());
    }

    #[test]
    fn quickstart_validates() {
        quickstart().validate().unwrap();
    }

    #[test]
    fn serve_preset_validates() {
        let p = serve();
        p.validate().unwrap();
        // serving must track drift: the schedule must not decay to zero
        assert!(matches!(p.base.vq.schedule, crate::vq::Schedule::Constant { .. }));
        assert!(matches!(p.base.scheme, SchemeConfig::AsyncDelta { .. }));
    }

    #[test]
    fn durable_serve_preset_validates() {
        let p = serve_durable("/tmp/dalvq-state");
        p.validate().unwrap();
        assert!(p.serve.state_dir.is_some());
        assert!(p.serve.checkpoint_every >= 1);
        // sharding composes with persistence
        let mut p = serve_durable("/tmp/dalvq-state");
        p.serve.shards = 4;
        p.serve.probe_n = 2;
        p.validate().unwrap();
    }

    #[test]
    fn rebalancing_serve_preset_validates() {
        let p = serve_rebalancing(4, "/tmp/dalvq-state", 1.8);
        p.validate().unwrap();
        assert_eq!(p.serve.rebalance_skew, 1.8);
        assert!(p.serve.state_dir.is_some());
        // the monitor cannot be armed without the durable migration source
        let mut p = serve_rebalancing(4, "/tmp/dalvq-state", 1.8);
        p.serve.state_dir = None;
        assert!(p.validate().is_err());
    }

    #[test]
    fn follower_serve_preset_validates() {
        let p = serve_follower("127.0.0.1:7171");
        p.validate().unwrap();
        assert_eq!(p.serve.follow.as_deref(), Some("127.0.0.1:7171"));
        assert!(p.serve.sync_every_ms >= 1);
        // a follower mirroring into its own state dir is valid too
        let mut p = serve_follower("127.0.0.1:7171");
        p.serve.state_dir = Some("/tmp/dalvq-follower".into());
        p.validate().unwrap();
    }

    #[test]
    fn sharded_serve_presets_validate() {
        for s in [1, 2, 4, 8] {
            let p = serve_sharded(s);
            p.validate().unwrap_or_else(|e| panic!("shards={s}: {e}"));
            assert_eq!(p.serve.shards, s);
            assert!(p.serve.probe_n >= 1 && p.serve.probe_n <= s);
        }
        // 3 does not divide kappa = 8
        assert!(serve_sharded(3).validate().is_err());
    }
}
