//! Config schema, validation and JSON (de)serialization.
//!
//! Configs round-trip through the in-tree JSON module (`util::json`):
//! `dalvq run --config exp.json` loads exactly what
//! [`ExperimentConfig::to_json_string`] writes.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::MixtureSpec;
use crate::runtime::EngineSpec;
use crate::sim::{CostModel, DelayModel};
use crate::util::Json;
use crate::vq::{InitMethod, Schedule};

/// Data generation parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct DataConfig {
    pub mixture: MixtureSpec,
    /// Total dataset size — split evenly across workers.
    pub n_total: usize,
    /// Held-out evaluation sample size for the `C_{n,M}` estimator.
    pub eval_points: usize,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self { mixture: MixtureSpec::default(), n_total: 40_000, eval_points: 2_048 }
    }
}

/// VQ algorithm parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct VqConfig {
    /// Number of prototypes κ.
    pub kappa: usize,
    pub schedule: Schedule,
    pub init: InitMethod,
}

impl Default for VqConfig {
    fn default() -> Self {
        Self {
            kappa: 16,
            schedule: Schedule::paper_default(),
            init: InitMethod::FromData,
        }
    }
}

/// Which parallelization scheme to run (the heart of the paper).
#[derive(Debug, Clone, PartialEq)]
pub enum SchemeConfig {
    /// Plain sequential VQ (the `M = 1` reference).
    Sequential,
    /// Scheme A, eq. 3: synchronous averaging every `tau` points.
    Averaging { tau: usize },
    /// Scheme B, eq. 8: synchronous delta merge every `tau` points.
    DeltaSync { tau: usize },
    /// Scheme C, eq. 9: asynchronous delta merge with stochastic delays.
    AsyncDelta {
        tau: usize,
        up_delay: DelayModel,
        down_delay: DelayModel,
    },
}

impl SchemeConfig {
    pub fn tau(&self) -> usize {
        match *self {
            SchemeConfig::Sequential => 1,
            SchemeConfig::Averaging { tau }
            | SchemeConfig::DeltaSync { tau }
            | SchemeConfig::AsyncDelta { tau, .. } => tau,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchemeConfig::Sequential => "sequential",
            SchemeConfig::Averaging { .. } => "averaging",
            SchemeConfig::DeltaSync { .. } => "delta_sync",
            SchemeConfig::AsyncDelta { .. } => "async_delta",
        }
    }
}

/// Run-length and observation parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Data points each worker processes over the run.
    pub points_per_worker: u64,
    /// Seconds of (virtual) wall time between distortion snapshots.
    pub eval_interval: f64,
    /// Max trace events retained (0 disables tracing).
    pub trace_capacity: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self { points_per_worker: 200_000, eval_interval: 0.01, trace_capacity: 0 }
    }
}

/// Cloud-runtime (FIG4) parameters: real concurrency with latency-injected
/// storage services.
#[derive(Debug, Clone, PartialEq)]
pub struct CloudConfig {
    /// Mean one-way blob/queue latency (seconds, real time).
    pub service_latency: f64,
    /// Jitter fraction of the latency (uniform ±).
    pub latency_jitter: f64,
    /// Probability a queue push is dropped before reaching the reducer
    /// (fault injection).
    pub drop_prob: f64,
    /// Points each worker processes between exchange attempts
    /// (the cloud analogue of tau; a multiple of tau).
    pub points_per_exchange: usize,
    /// Real seconds of compute per data point — the worker paces itself to
    /// this rate, grounding the wall-clock axis the way the paper's VM
    /// per-point cost did (the native engine is far faster than a 2012
    /// Azure VM; without pacing the latency/compute ratio — the quantity
    /// Figure 4 is about — would be wildly off).
    pub point_compute: f64,
}

impl Default for CloudConfig {
    fn default() -> Self {
        Self {
            service_latency: 0.0005,
            latency_jitter: 0.5,
            drop_prob: 0.0,
            points_per_exchange: 100,
            point_compute: 1e-5,
        }
    }
}

/// Serving-subsystem parameters: the long-running `dalvq serve` fleet
/// (online eq.-9 training + query read path behind a TCP front-end).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Bind address for the TCP front-end (`:0` = ephemeral port).
    pub addr: String,
    /// Codebook shards `S`: the prototype space is partitioned across this
    /// many independent fleets behind a coarse quantizer (1 = the single-
    /// fleet deployment). `kappa` must divide evenly into `shards`.
    pub shards: usize,
    /// Shards probed per query point (multi-probe): the `probe_n` nearest
    /// coarse cells are scanned, recovering nearest/distortion correctness
    /// near shard boundaries. Must be in `1..=shards`.
    pub probe_n: usize,
    /// Bootstrap sample size for the coarse quantizer's k-means pass
    /// (capped at the dataset size).
    pub router_sample: usize,
    /// Lloyd iterations of the coarse quantizer's k-means pass.
    pub router_iters: usize,
    /// Points each worker trains between exchange attempts (multiple of tau).
    pub points_per_exchange: usize,
    /// Publish a query snapshot every this many reducer folds (1 = every
    /// fold; larger trades read freshness for reducer throughput).
    pub publish_every: u64,
    /// Bound on queued ingest batches per worker (admission control: full
    /// channels shed load rather than block the query path).
    pub ingest_queue: usize,
    /// Max ingested points a worker absorbs per chunk boundary.
    pub absorb_per_chunk: usize,
    /// Real seconds of compute per trained point; 0 = free-running.
    pub point_compute: f64,
    /// Mean one-way latency injected on the workers' exchange path
    /// (seconds; the serving analogue of [`CloudConfig::service_latency`]).
    pub service_latency: f64,
    /// Jitter fraction of that latency (uniform ±).
    pub latency_jitter: f64,
    /// Probability a delta upload is dropped (fault injection).
    pub drop_prob: f64,
    /// Start the training fleet paused; [`crate::serve::VqService::resume`]
    /// releases it. Lets a caller preload the ingest queues before any
    /// training happens (the determinism suite depends on this).
    pub start_paused: bool,
    /// Synchronous exchanges: each worker blocks until the reducer has
    /// folded its delta before training on. Deterministic per seed with
    /// one worker per shard; incompatible with `drop_prob > 0`.
    pub sync_exchange: bool,
    /// Stop each worker after training this many points (0 = open-ended).
    /// Bounded training makes a run's endpoint a function of the config
    /// rather than of shutdown timing.
    pub max_points_per_worker: u64,
    /// Durable state directory (`None` = no persistence). When set, the
    /// service checkpoints each shard's codebook into it and a restart
    /// with the same directory resumes at the saved shard versions
    /// instead of retraining (router restored, fleets seeded from the
    /// saved codebooks).
    pub state_dir: Option<PathBuf>,
    /// Reducer folds between automatic checkpoints of a shard (the
    /// background checkpointer also flushes on `Checkpoint` requests and
    /// at shutdown). Only meaningful with `state_dir`.
    pub checkpoint_every: u64,
    /// Auto-rebalance trigger: when the max/mean ratio of per-shard
    /// ingest (points accepted this router epoch) exceeds this, the skew
    /// monitor re-partitions the service online (router retrained from
    /// the checkpointed codebooks, prototype rows migrated across
    /// shards). `0.0` disables the monitor; meaningful values are `> 1`
    /// (1 = perfectly balanced). Requires `state_dir` — the checkpointed
    /// files are the migration source.
    pub rebalance_skew: f64,
    /// Folds that must land in the current router epoch (summed across
    /// shards) before the skew trigger may fire — the shard codebooks
    /// must have adapted to the load the retrainer will weight by, and a
    /// fresh epoch must not be churned by startup transients.
    pub rebalance_min_folds: u64,
    /// Follow a leader (`Some("host:port")`): start as a **read-only
    /// follower** that warm-starts from — and keeps re-syncing to — the
    /// leader's shipped checkpoints instead of training its own fleets.
    /// The deployment shape (shards, kappa, dim) is adopted from the
    /// leader's manifest; writes answer `NotLeader`. `None` (default) =
    /// a normal leader. With `state_dir` also set, the follower mirrors
    /// every adopted bundle locally.
    pub follow: Option<String>,
    /// Milliseconds between a follower's sync polls of the leader's
    /// checkpoint generation. Only meaningful with `follow`.
    pub sync_every_ms: u64,
    /// Consecutive failed sync polls after which a mirrored follower
    /// **promotes itself to leader** from its byte-identical local
    /// mirror (automatic failover): lost-contact budget ≈
    /// `sync_every_ms * miss_threshold`. `0` (default) disarms failover
    /// — the follower retries forever. Arming it requires both `follow`
    /// and `state_dir` (a mirror-less follower has nothing to promote
    /// from).
    pub miss_threshold: u64,
    /// Slow-query log threshold in microseconds: any request whose
    /// end-to-end handling exceeds this emits a `slow_query` journal
    /// event (op, total µs, route/scan stage breakdown) and bumps the
    /// `slow_queries` counter. `0` (default) disables the log.
    pub slow_query_us: u64,
    /// Periodic telemetry snapshot file (`None` = disabled). When set, a
    /// background thread writes the full [`crate::obs`] snapshot —
    /// counters, gauges, histogram summaries, recent events — to this
    /// path as pretty JSON every `metrics_every_ms`, plus once at
    /// shutdown, so a scrape or a post-run assertion never needs the
    /// wire `Metrics` op.
    pub metrics_file: Option<PathBuf>,
    /// Milliseconds between metrics-file snapshots. Only meaningful with
    /// `metrics_file`.
    pub metrics_every_ms: u64,
    /// Cross-request micro-batch coalescing window in microseconds: read
    /// requests (encode/nearest/distortion) arriving within this window
    /// queue into one fused scan per probed shard instead of scanning
    /// individually. `0` (default) disables coalescing — every request
    /// scans on its own connection thread, exactly the pre-batching
    /// behavior. Answers are bit-identical either way; coalescing trades
    /// up to one window of added latency for shard-codebook cache reuse
    /// across requests.
    pub batch_window_us: u64,
    /// Point budget of one coalesced micro-batch: the batcher drains as
    /// soon as the queued requests hold this many points, even before
    /// the window closes. Bounds both reply latency under load and the
    /// size of the fused scan. Only meaningful with `batch_window_us`.
    pub batch_max_points: usize,
    /// Distributed-tracing sample rate: `0` (default) disarms tracing,
    /// `1` traces every request, `N > 1` deterministically keeps one
    /// request in `N`. Independently of the draw, any request slower
    /// than `slow_query_us` is kept, and wire-propagated trace contexts
    /// (a client or follower asking for its own trace) are always
    /// honored. Completed traces land in a bounded ring served by the
    /// `Trace` wire op, `dalvq trace`, and `--metrics-file` snapshots.
    pub trace_sample: u64,
    /// Event-journal ring capacity (entries retained). A busy rebalance
    /// plus sync cycle can wrap a small ring before anyone reads it;
    /// raise this to keep more history. Validated `>= 16`.
    pub journal_capacity: usize,
    /// Request-handler threads behind the event-loop front-end. `0`
    /// (default) sizes the pool to the machine's available parallelism;
    /// an explicit value pins it (validated `<= 1024`). The reactor
    /// itself is always one thread — this pool only runs decode /
    /// dispatch / encode. The pool is shared by every connection, so a
    /// slow op (`Rebalance`'s epoch swap, `FetchState` shipping,
    /// `Checkpoint`, a coalesced-batch wait) occupies a worker for its
    /// whole duration; deployments that issue admin ops under load
    /// should raise this above the core count to keep fast reads from
    /// queueing behind them.
    pub io_workers: usize,
    /// Per-connection in-flight quota: at most this many requests may
    /// be parsed but not yet answered on one connection — queued,
    /// executing, or completed but still waiting behind an earlier
    /// reply; excess pipelined frames answer `Throttled` in-band (the
    /// connection survives). `0` (default) disables the quota —
    /// backpressure then falls to the reactor's parse-ahead bound and
    /// TCP flow control. Values at or above that bound (64) never trip:
    /// the reactor pauses parsing before the quota is reached.
    pub max_inflight: usize,
    /// Per-connection rate quota in requests/second (token bucket with
    /// a one-second burst). Requests past the budget answer `Throttled`
    /// with a retry-after hint. `0` (default) disables the quota.
    pub rate_limit: u64,
    /// Brownout watermark over the `shard.<s>.queue_depth` gauges: when
    /// any shard's ingest queue sits at or above this depth, the
    /// front-end sheds *ingest* frames with `Throttled` — reads are
    /// never shed — until the queues drain below it. Entry and exit are
    /// journaled (`brownout.enter` / `brownout.exit`). `0` (default)
    /// disables brownout.
    pub brownout_depth: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            shards: 1,
            probe_n: 1,
            router_sample: 4_096,
            router_iters: 8,
            points_per_exchange: 100,
            publish_every: 1,
            ingest_queue: 64,
            absorb_per_chunk: 1_024,
            point_compute: 0.0,
            service_latency: 0.0,
            latency_jitter: 0.0,
            drop_prob: 0.0,
            start_paused: false,
            sync_exchange: false,
            max_points_per_worker: 0,
            state_dir: None,
            checkpoint_every: 64,
            rebalance_skew: 0.0,
            rebalance_min_folds: 64,
            follow: None,
            sync_every_ms: 500,
            miss_threshold: 0,
            slow_query_us: 0,
            metrics_file: None,
            metrics_every_ms: 1_000,
            batch_window_us: 0,
            batch_max_points: 4_096,
            trace_sample: 0,
            journal_capacity: 256,
            io_workers: 0,
            max_inflight: 0,
            rate_limit: 0,
            brownout_depth: 0,
        }
    }
}

impl ServeConfig {
    /// Validate against the experiment it will serve.
    pub fn validate(&self, base: &ExperimentConfig) -> Result<()> {
        let mut errs: Vec<String> = Vec::new();
        if self.addr.is_empty() {
            errs.push("addr must be a host:port bind address".into());
        }
        if let Some(leader) = &self.follow {
            // Follower: the serving topology (shards, kappa, dim) is
            // adopted from the leader's manifest, so the local sharding
            // constraints don't apply — only follower-specific ones do.
            if leader.is_empty() {
                errs.push("follow must be the leader's host:port".into());
            }
            if self.probe_n == 0 {
                errs.push(
                    "probe_n must be >= 1 (it is clamped to the leader's \
                     shard count at adoption)"
                        .into(),
                );
            }
            if self.sync_every_ms == 0 {
                errs.push("sync_every_ms must be >= 1".into());
            }
            if self.miss_threshold > 0 && self.state_dir.is_none() {
                errs.push(
                    "miss_threshold (automatic failover) requires \
                     state_dir: promotion serves from the follower's \
                     local mirror"
                        .into(),
                );
            }
            if self.rebalance_skew > 0.0 {
                errs.push(
                    "a follower is read-only and cannot rebalance; arm \
                     rebalance_skew on the leader instead"
                        .into(),
                );
            }
        } else if self.shards == 0 {
            errs.push("shards must be >= 1".into());
        } else {
            if base.vq.kappa % self.shards != 0 {
                errs.push(format!(
                    "kappa = {} must divide evenly across shards = {}",
                    base.vq.kappa, self.shards
                ));
            }
            if !(1..=self.shards).contains(&self.probe_n) {
                errs.push(format!(
                    "probe_n = {} must be in 1..={} (the shard count)",
                    self.probe_n, self.shards
                ));
            }
            if self.router_sample < self.shards {
                errs.push(format!(
                    "router_sample = {} cannot seed {} coarse centroids",
                    self.router_sample, self.shards
                ));
            }
            if base.data.n_total < self.shards * base.m.max(1) {
                errs.push(format!(
                    "n_total = {} cannot bootstrap {} shards x {} workers",
                    base.data.n_total, self.shards, base.m
                ));
            }
        }
        if self.miss_threshold > 0 && self.follow.is_none() {
            errs.push(
                "miss_threshold (automatic failover) only applies to a \
                 follower; set follow"
                    .into(),
            );
        }
        if self.sync_exchange && self.drop_prob > 0.0 {
            errs.push(
                "sync_exchange waits for every delta to fold; \
                 drop_prob must be 0"
                    .into(),
            );
        }
        let tau = base.scheme.tau();
        if self.points_per_exchange == 0
            || self.points_per_exchange % tau != 0
        {
            errs.push(format!(
                "points_per_exchange = {} must be a positive multiple of \
                 tau = {tau}",
                self.points_per_exchange
            ));
        }
        if self.publish_every == 0 {
            errs.push("publish_every must be >= 1".into());
        }
        if self.ingest_queue == 0 {
            errs.push("ingest_queue must be >= 1".into());
        }
        if self.absorb_per_chunk == 0 {
            errs.push("absorb_per_chunk must be >= 1".into());
        }
        if self.point_compute < 0.0 || !self.point_compute.is_finite() {
            errs.push("point_compute must be finite and >= 0".into());
        }
        if self.service_latency < 0.0 || !self.service_latency.is_finite() {
            errs.push("service_latency must be finite and >= 0".into());
        }
        if !(0.0..=1.0).contains(&self.latency_jitter) {
            errs.push("latency_jitter must be in [0, 1]".into());
        }
        if !(0.0..=1.0).contains(&self.drop_prob) {
            errs.push("drop_prob must be in [0, 1]".into());
        }
        if let Some(dir) = &self.state_dir {
            if dir.as_os_str().is_empty() {
                errs.push("state_dir must be a non-empty path".into());
            }
        }
        if self.checkpoint_every == 0 {
            errs.push("checkpoint_every must be >= 1".into());
        }
        if !self.rebalance_skew.is_finite() || self.rebalance_skew < 0.0 {
            errs.push("rebalance_skew must be finite and >= 0".into());
        } else if self.rebalance_skew > 0.0 {
            if self.rebalance_skew <= 1.0 {
                errs.push(format!(
                    "rebalance_skew = {} would trigger on a perfectly \
                     balanced fleet; use a ratio > 1 (or 0 to disable)",
                    self.rebalance_skew
                ));
            }
            if self.state_dir.is_none() {
                errs.push(
                    "rebalance_skew needs state_dir: a rebalance migrates \
                     the checkpointed shard files"
                        .into(),
                );
            }
        }
        if let Some(path) = &self.metrics_file {
            if path.as_os_str().is_empty() {
                errs.push("metrics_file must be a non-empty path".into());
            }
            if self.metrics_every_ms == 0 {
                errs.push("metrics_every_ms must be >= 1".into());
            }
        }
        if self.batch_window_us > 0 && self.batch_max_points == 0 {
            errs.push(
                "batch_max_points must be >= 1 when batch_window_us arms \
                 the coalescer"
                    .into(),
            );
        }
        if self.journal_capacity < 16 {
            errs.push(format!(
                "journal_capacity = {} must be >= 16 (the ring must hold \
                 at least a burst of lifecycle events)",
                self.journal_capacity
            ));
        }
        if self.io_workers > 1024 {
            errs.push(format!(
                "io_workers = {} is past any plausible core count; use 0 \
                 to size the pool automatically",
                self.io_workers
            ));
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(anyhow!("invalid serve config:\n  - {}", errs.join("\n  - ")))
        }
    }
}

/// One experiment: a scheme, `M` workers, data, costs and an engine.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Number of computing entities `M`.
    pub m: usize,
    pub data: DataConfig,
    pub vq: VqConfig,
    pub scheme: SchemeConfig,
    pub cost: CostModel,
    pub run: RunConfig,
    pub engine: EngineSpec,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            seed: 20120427, // ESANN 2012 conference date
            m: 1,
            data: DataConfig::default(),
            vq: VqConfig::default(),
            scheme: SchemeConfig::DeltaSync { tau: 10 },
            cost: CostModel::default(),
            run: RunConfig::default(),
            engine: EngineSpec::Native,
        }
    }
}

impl ExperimentConfig {
    /// Validate the whole config; aggregates every problem found.
    pub fn validate(&self) -> Result<()> {
        let mut errs: Vec<String> = Vec::new();
        if self.m == 0 {
            errs.push("m must be >= 1".into());
        }
        if let Err(e) = self.data.mixture.validate() {
            errs.push(format!("mixture: {e}"));
        }
        if self.data.n_total < self.m {
            errs.push(format!(
                "n_total = {} cannot shard over m = {} workers",
                self.data.n_total, self.m
            ));
        }
        if self.data.eval_points == 0 {
            errs.push("eval_points must be positive".into());
        }
        if self.vq.kappa == 0 {
            errs.push("kappa must be >= 1".into());
        }
        if self.vq.kappa > self.data.n_total {
            errs.push("kappa exceeds dataset size".into());
        }
        if let Err(e) = self.vq.schedule.validate() {
            errs.push(format!("schedule: {e}"));
        }
        if self.scheme.tau() == 0 {
            errs.push("tau must be >= 1".into());
        }
        if let SchemeConfig::AsyncDelta { up_delay, down_delay, .. } = &self.scheme {
            if let Err(e) = up_delay.validate() {
                errs.push(format!("up_delay: {e}"));
            }
            if let Err(e) = down_delay.validate() {
                errs.push(format!("down_delay: {e}"));
            }
        }
        if let Err(e) = self.cost.validate() {
            errs.push(format!("cost: {e}"));
        }
        if self.run.points_per_worker == 0 {
            errs.push("points_per_worker must be positive".into());
        }
        if !(self.run.eval_interval > 0.0) {
            errs.push("eval_interval must be positive".into());
        }
        if self.run.points_per_worker % self.scheme.tau() as u64 != 0 {
            errs.push(format!(
                "points_per_worker = {} must be a multiple of tau = {}",
                self.run.points_per_worker,
                self.scheme.tau()
            ));
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(anyhow!("invalid config:\n  - {}", errs.join("\n  - ")))
        }
    }

    /// Sample dimension, derived from the mixture.
    pub fn dim(&self) -> usize {
        self.data.mixture.dim
    }

    // ------------------------------------------------------------- JSON

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("seed", self.seed)
            .set("m", self.m)
            .set(
                "data",
                Json::obj()
                    .set("mixture", mixture_to_json(&self.data.mixture))
                    .set("n_total", self.data.n_total)
                    .set("eval_points", self.data.eval_points),
            )
            .set(
                "vq",
                Json::obj()
                    .set("kappa", self.vq.kappa)
                    .set("schedule", schedule_to_json(&self.vq.schedule))
                    .set("init", init_to_json(self.vq.init)),
            )
            .set("scheme", scheme_to_json(&self.scheme))
            .set("cost", cost_to_json(&self.cost))
            .set(
                "run",
                Json::obj()
                    .set("points_per_worker", self.run.points_per_worker)
                    .set("eval_interval", self.run.eval_interval)
                    .set("trace_capacity", self.run.trace_capacity),
            )
            .set("engine", engine_to_json(&self.engine))
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_pretty()
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let data = j.req("data")?;
        let vq = j.req("vq")?;
        let run = j.req("run")?;
        let cfg = Self {
            seed: j.req("seed")?.as_u64()?,
            m: j.req("m")?.as_usize()?,
            data: DataConfig {
                mixture: mixture_from_json(data.req("mixture")?)?,
                n_total: data.req("n_total")?.as_usize()?,
                eval_points: data.req("eval_points")?.as_usize()?,
            },
            vq: VqConfig {
                kappa: vq.req("kappa")?.as_usize()?,
                schedule: schedule_from_json(vq.req("schedule")?)?,
                init: init_from_json(vq.req("init")?)?,
            },
            scheme: scheme_from_json(j.req("scheme")?)?,
            cost: cost_from_json(j.req("cost")?)?,
            run: RunConfig {
                points_per_worker: run.req("points_per_worker")?.as_u64()?,
                eval_interval: run.req("eval_interval")?.as_f64()?,
                trace_capacity: run.req("trace_capacity")?.as_usize()?,
            },
            engine: engine_from_json(j.req("engine")?)?,
        };
        Ok(cfg)
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        let cfg = Self::from_json(&Json::parse(text).context("parsing config JSON")?)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json_str(&text)
    }
}

// ------------------------------------------------------- leaf converters

fn mixture_to_json(m: &MixtureSpec) -> Json {
    Json::obj()
        .set("components", m.components)
        .set("dim", m.dim)
        .set("separation", m.separation as f64)
        .set("std", m.std as f64)
        .set("imbalance", m.imbalance as f64)
        .set("noise_frac", m.noise_frac as f64)
}

fn mixture_from_json(j: &Json) -> Result<MixtureSpec> {
    Ok(MixtureSpec {
        components: j.req("components")?.as_usize()?,
        dim: j.req("dim")?.as_usize()?,
        separation: j.req("separation")?.as_f32()?,
        std: j.req("std")?.as_f32()?,
        imbalance: j.req("imbalance")?.as_f32()?,
        noise_frac: j.req("noise_frac")?.as_f32()?,
    })
}

fn schedule_to_json(s: &Schedule) -> Json {
    match *s {
        Schedule::Constant { eps0 } => {
            Json::obj().set("kind", "constant").set("eps0", eps0 as f64)
        }
        Schedule::InverseTime { eps0, half_life } => Json::obj()
            .set("kind", "inverse_time")
            .set("eps0", eps0 as f64)
            .set("half_life", half_life as f64),
        Schedule::Power { eps0, half_life, alpha } => Json::obj()
            .set("kind", "power")
            .set("eps0", eps0 as f64)
            .set("half_life", half_life as f64)
            .set("alpha", alpha as f64),
    }
}

fn schedule_from_json(j: &Json) -> Result<Schedule> {
    Ok(match j.req("kind")?.as_str()? {
        "constant" => Schedule::Constant { eps0: j.req("eps0")?.as_f32()? },
        "inverse_time" => Schedule::InverseTime {
            eps0: j.req("eps0")?.as_f32()?,
            half_life: j.req("half_life")?.as_f32()?,
        },
        "power" => Schedule::Power {
            eps0: j.req("eps0")?.as_f32()?,
            half_life: j.req("half_life")?.as_f32()?,
            alpha: j.req("alpha")?.as_f32()?,
        },
        other => bail!("unknown schedule kind {other:?}"),
    })
}

fn init_to_json(i: InitMethod) -> Json {
    Json::Str(
        match i {
            InitMethod::FromData => "from_data",
            InitMethod::Gaussian => "gaussian",
            InitMethod::KmeansPlusPlus => "kmeans_plus_plus",
        }
        .into(),
    )
}

fn init_from_json(j: &Json) -> Result<InitMethod> {
    Ok(match j.as_str()? {
        "from_data" => InitMethod::FromData,
        "gaussian" => InitMethod::Gaussian,
        "kmeans_plus_plus" => InitMethod::KmeansPlusPlus,
        other => bail!("unknown init method {other:?}"),
    })
}

fn delay_to_json(d: &DelayModel) -> Json {
    match *d {
        DelayModel::Instant => Json::obj().set("kind", "instant"),
        DelayModel::Fixed { secs } => {
            Json::obj().set("kind", "fixed").set("secs", secs)
        }
        DelayModel::Geometric { p, unit } => Json::obj()
            .set("kind", "geometric")
            .set("p", p)
            .set("unit", unit),
    }
}

fn delay_from_json(j: &Json) -> Result<DelayModel> {
    Ok(match j.req("kind")?.as_str()? {
        "instant" => DelayModel::Instant,
        "fixed" => DelayModel::Fixed { secs: j.req("secs")?.as_f64()? },
        "geometric" => DelayModel::Geometric {
            p: j.req("p")?.as_f64()?,
            unit: j.req("unit")?.as_f64()?,
        },
        other => bail!("unknown delay kind {other:?}"),
    })
}

fn scheme_to_json(s: &SchemeConfig) -> Json {
    match s {
        SchemeConfig::Sequential => Json::obj().set("kind", "sequential"),
        SchemeConfig::Averaging { tau } => {
            Json::obj().set("kind", "averaging").set("tau", *tau)
        }
        SchemeConfig::DeltaSync { tau } => {
            Json::obj().set("kind", "delta_sync").set("tau", *tau)
        }
        SchemeConfig::AsyncDelta { tau, up_delay, down_delay } => Json::obj()
            .set("kind", "async_delta")
            .set("tau", *tau)
            .set("up_delay", delay_to_json(up_delay))
            .set("down_delay", delay_to_json(down_delay)),
    }
}

fn scheme_from_json(j: &Json) -> Result<SchemeConfig> {
    Ok(match j.req("kind")?.as_str()? {
        "sequential" => SchemeConfig::Sequential,
        "averaging" => SchemeConfig::Averaging { tau: j.req("tau")?.as_usize()? },
        "delta_sync" => SchemeConfig::DeltaSync { tau: j.req("tau")?.as_usize()? },
        "async_delta" => SchemeConfig::AsyncDelta {
            tau: j.req("tau")?.as_usize()?,
            up_delay: delay_from_json(j.req("up_delay")?)?,
            down_delay: delay_from_json(j.req("down_delay")?)?,
        },
        other => bail!("unknown scheme kind {other:?}"),
    })
}

fn cost_to_json(c: &CostModel) -> Json {
    Json::obj()
        .set("point_compute", c.point_compute)
        .set("merge_cost", c.merge_cost)
        .set("broadcast_cost", c.broadcast_cost)
        .set(
            "speed_factors",
            Json::Arr(c.speed_factors.iter().map(|s| Json::Num(*s)).collect()),
        )
}

fn cost_from_json(j: &Json) -> Result<CostModel> {
    Ok(CostModel {
        point_compute: j.req("point_compute")?.as_f64()?,
        merge_cost: j.req("merge_cost")?.as_f64()?,
        broadcast_cost: j.req("broadcast_cost")?.as_f64()?,
        speed_factors: j
            .req("speed_factors")?
            .as_arr()?
            .iter()
            .map(|x| x.as_f64())
            .collect::<Result<Vec<_>>>()?,
    })
}

fn engine_to_json(e: &EngineSpec) -> Json {
    match e {
        EngineSpec::Native => Json::obj().set("kind", "native"),
        EngineSpec::Pjrt { artifacts_dir, variant } => Json::obj()
            .set("kind", "pjrt")
            .set("artifacts_dir", artifacts_dir.display().to_string())
            .set("variant", variant.clone()),
    }
}

fn engine_from_json(j: &Json) -> Result<EngineSpec> {
    Ok(match j.req("kind")?.as_str()? {
        "native" => EngineSpec::Native,
        "pjrt" => EngineSpec::Pjrt {
            artifacts_dir: PathBuf::from(j.req("artifacts_dir")?.as_str()?),
            variant: j.req("variant")?.as_str()?.to_string(),
        },
        other => bail!("unknown engine kind {other:?}"),
    })
}

/// A paper figure: one base experiment swept over worker counts.
#[derive(Debug, Clone, PartialEq)]
pub struct FigureConfig {
    /// `"fig1"` … `"fig4"` (or an ablation id).
    pub id: String,
    /// Paper caption, reproduced in reports.
    pub title: String,
    pub base: ExperimentConfig,
    /// The `M` values of the figure (paper: {1, 2, 10}, cloud: up to 32).
    pub ms: Vec<usize>,
    /// Cloud-runtime parameters (only used by the FIG4 path).
    pub cloud: Option<CloudConfig>,
}

impl FigureConfig {
    pub fn validate(&self) -> Result<()> {
        if self.ms.is_empty() {
            return Err(anyhow!("figure {} has no worker counts", self.id));
        }
        for &m in &self.ms {
            let mut cfg = self.base.clone();
            cfg.m = m;
            cfg.validate()
                .with_context(|| format!("figure {} at M={m}", self.id))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_round_trip_default() {
        let cfg = ExperimentConfig::default();
        let text = cfg.to_json_string();
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn json_round_trip_async_pjrt() {
        let mut cfg = ExperimentConfig::default();
        cfg.scheme = SchemeConfig::AsyncDelta {
            tau: 10,
            up_delay: DelayModel::Geometric { p: 0.25, unit: 1e-4 },
            down_delay: DelayModel::Fixed { secs: 0.001 },
        };
        cfg.engine = EngineSpec::pjrt_default("k16d16");
        cfg.cost.speed_factors = vec![1.0, 2.5];
        cfg.vq.init = InitMethod::KmeansPlusPlus;
        cfg.vq.schedule =
            Schedule::Power { eps0: 0.4, half_life: 200.0, alpha: 0.75 };
        let back = ExperimentConfig::from_json_str(&cfg.to_json_string()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn validation_aggregates_errors() {
        let mut cfg = ExperimentConfig::default();
        cfg.m = 0;
        cfg.vq.kappa = 0;
        cfg.run.eval_interval = -1.0;
        let msg = format!("{:#}", cfg.validate().unwrap_err());
        assert!(msg.contains("m must be"), "{msg}");
        assert!(msg.contains("kappa"), "{msg}");
        assert!(msg.contains("eval_interval"), "{msg}");
    }

    #[test]
    fn tau_multiple_enforced() {
        let mut cfg = ExperimentConfig::default();
        cfg.scheme = SchemeConfig::DeltaSync { tau: 7 };
        cfg.run.points_per_worker = 100; // not a multiple of 7
        assert!(cfg.validate().is_err());
        cfg.run.points_per_worker = 700;
        cfg.validate().unwrap();
    }

    #[test]
    fn async_delay_validated() {
        let mut cfg = ExperimentConfig::default();
        cfg.scheme = SchemeConfig::AsyncDelta {
            tau: 10,
            up_delay: DelayModel::Geometric { p: 2.0, unit: 1.0 },
            down_delay: DelayModel::Instant,
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn figure_validates_every_m() {
        let fig = FigureConfig {
            id: "t".into(),
            title: "t".into(),
            base: ExperimentConfig::default(),
            ms: vec![1, 2, 100_000],
            cloud: None,
        };
        // 100k workers cannot shard 40k points
        assert!(fig.validate().is_err());
    }

    #[test]
    fn serve_config_validates_against_its_base() {
        let base = ExperimentConfig::default(); // tau = 10
        ServeConfig::default().validate(&base).unwrap();

        let mut s = ServeConfig::default();
        s.points_per_exchange = 55; // not a multiple of tau
        assert!(s.validate(&base).is_err());

        let mut s = ServeConfig::default();
        s.publish_every = 0;
        s.drop_prob = 1.5;
        s.addr = String::new();
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("publish_every"), "{msg}");
        assert!(msg.contains("drop_prob"), "{msg}");
        assert!(msg.contains("addr"), "{msg}");
    }

    #[test]
    fn rebalance_knobs_are_validated() {
        let base = ExperimentConfig::default();

        // auto-rebalance without durable state is meaningless
        let mut s = ServeConfig::default();
        s.rebalance_skew = 2.0;
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("state_dir"), "{msg}");

        // a ratio <= 1 would fire constantly
        let mut s = ServeConfig::default();
        s.state_dir = Some(std::path::PathBuf::from("/tmp/x"));
        s.rebalance_skew = 0.8;
        assert!(s.validate(&base).is_err());
        s.rebalance_skew = f64::NAN;
        assert!(s.validate(&base).is_err());

        // a sane trigger over a durable sharded deployment is accepted
        let mut s = ServeConfig::default();
        s.state_dir = Some(std::path::PathBuf::from("/tmp/x"));
        s.shards = 4;
        s.probe_n = 2;
        s.rebalance_skew = 1.8;
        s.rebalance_min_folds = 16;
        s.validate(&base).unwrap();

        // 0 disables the monitor and needs nothing else
        let mut s = ServeConfig::default();
        s.rebalance_skew = 0.0;
        s.validate(&base).unwrap();
    }

    #[test]
    fn follower_knobs_are_validated() {
        let base = ExperimentConfig::default();

        // a plain follower config is fine — local sharding constraints
        // don't apply (the topology is adopted from the leader)
        let mut s = ServeConfig::default();
        s.follow = Some("127.0.0.1:7171".into());
        s.shards = 0; // would be rejected on a leader
        s.validate(&base).unwrap();

        // the leader address must be present
        let mut s = ServeConfig::default();
        s.follow = Some(String::new());
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("host:port"), "{msg}");

        // a follower cannot arm the rebalance monitor
        let mut s = ServeConfig::default();
        s.follow = Some("127.0.0.1:7171".into());
        s.state_dir = Some(std::path::PathBuf::from("/tmp/x"));
        s.rebalance_skew = 1.5;
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("read-only"), "{msg}");

        // the sync cadence must be positive
        let mut s = ServeConfig::default();
        s.follow = Some("127.0.0.1:7171".into());
        s.sync_every_ms = 0;
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("sync_every_ms"), "{msg}");

        // failover needs a mirror to promote from
        let mut s = ServeConfig::default();
        s.follow = Some("127.0.0.1:7171".into());
        s.miss_threshold = 3;
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("state_dir"), "{msg}");
        s.state_dir = Some(std::path::PathBuf::from("/tmp/x"));
        s.validate(&base).unwrap();

        // ... and only makes sense on a follower
        let mut s = ServeConfig::default();
        s.miss_threshold = 3;
        s.state_dir = Some(std::path::PathBuf::from("/tmp/x"));
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("follow"), "{msg}");
    }

    #[test]
    fn serve_sharding_is_validated() {
        let base = ExperimentConfig::default(); // kappa = 16

        let mut s = ServeConfig::default();
        s.shards = 4;
        s.probe_n = 2;
        s.validate(&base).unwrap();

        // kappa must divide across shards
        let mut s = ServeConfig::default();
        s.shards = 3;
        assert!(s.validate(&base).is_err());

        // probe width bounded by the shard count
        let mut s = ServeConfig::default();
        s.shards = 4;
        s.probe_n = 5;
        assert!(s.validate(&base).is_err());
        s.probe_n = 0;
        assert!(s.validate(&base).is_err());

        // zero shards is rejected outright
        let mut s = ServeConfig::default();
        s.shards = 0;
        assert!(s.validate(&base).is_err());

        // sync exchanges wait on folds: lossy transport cannot be combined
        let mut s = ServeConfig::default();
        s.sync_exchange = true;
        s.drop_prob = 0.1;
        assert!(s.validate(&base).is_err());
        s.drop_prob = 0.0;
        s.validate(&base).unwrap();
    }

    #[test]
    fn serve_persistence_is_validated() {
        let base = ExperimentConfig::default();

        let mut s = ServeConfig::default();
        s.state_dir = Some(PathBuf::from("/tmp/dalvq-state"));
        s.checkpoint_every = 10;
        s.validate(&base).unwrap();

        let mut s = ServeConfig::default();
        s.state_dir = Some(PathBuf::new());
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("state_dir"), "{msg}");

        let mut s = ServeConfig::default();
        s.checkpoint_every = 0;
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("checkpoint_every"), "{msg}");
    }

    #[test]
    fn telemetry_knobs_are_validated() {
        let base = ExperimentConfig::default();

        // snapshots on a sane cadence, plus an armed slow-query log
        let mut s = ServeConfig::default();
        s.metrics_file = Some(PathBuf::from("/tmp/dalvq-metrics.json"));
        s.metrics_every_ms = 250;
        s.slow_query_us = 5_000;
        s.validate(&base).unwrap();

        // an empty snapshot path is a config typo, not "disabled"
        let mut s = ServeConfig::default();
        s.metrics_file = Some(PathBuf::new());
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("metrics_file"), "{msg}");

        // a zero cadence only matters when snapshots are armed
        let mut s = ServeConfig::default();
        s.metrics_every_ms = 0;
        s.validate(&base).unwrap();
        s.metrics_file = Some(PathBuf::from("/tmp/dalvq-metrics.json"));
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("metrics_every_ms"), "{msg}");
    }

    #[test]
    fn batching_knobs_are_validated() {
        let base = ExperimentConfig::default();

        // a sane armed batcher
        let mut s = ServeConfig::default();
        s.batch_window_us = 200;
        s.batch_max_points = 1_024;
        s.validate(&base).unwrap();

        // a zero point budget starves the armed batcher
        let mut s = ServeConfig::default();
        s.batch_window_us = 200;
        s.batch_max_points = 0;
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("batch_max_points"), "{msg}");

        // with the batcher off, the point budget is inert
        let mut s = ServeConfig::default();
        s.batch_max_points = 0;
        s.validate(&base).unwrap();
    }

    #[test]
    fn admission_knobs_are_validated() {
        let base = ExperimentConfig::default();

        // armed quotas and a pinned worker pool are accepted
        let mut s = ServeConfig::default();
        s.io_workers = 8;
        s.max_inflight = 16;
        s.rate_limit = 1_000;
        s.brownout_depth = 4;
        s.validate(&base).unwrap();

        // everything-off is the default and stays valid
        ServeConfig::default().validate(&base).unwrap();

        // an absurd worker count is a typo, not a deployment
        let mut s = ServeConfig::default();
        s.io_workers = 4_096;
        let msg = format!("{:#}", s.validate(&base).unwrap_err());
        assert!(msg.contains("io_workers"), "{msg}");
    }

    #[test]
    fn bad_json_reports_key() {
        let mut text = ExperimentConfig::default().to_json_string();
        text = text.replace("\"kind\": \"delta_sync\"", "\"kind\": \"nope\"");
        let err = format!("{:#}", ExperimentConfig::from_json_str(&text).unwrap_err());
        assert!(err.contains("nope"), "{err}");
    }
}
