//! Typed configuration: schema, validation, TOML I/O and paper presets.
//!
//! Every run of the system — CLI, examples, benches, tests — is described
//! by an [`ExperimentConfig`] (one scheme, one `M`) or a [`FigureConfig`]
//! (one paper figure = one scheme swept over several `M`). Presets in
//! [`presets`] encode the exact parameterizations of the paper's Figures
//! 1–4 and the two ablations from DESIGN.md.

mod schema;

pub mod presets;

pub use schema::{
    CloudConfig, DataConfig, ExperimentConfig, FigureConfig, RunConfig,
    SchemeConfig, ServeConfig, VqConfig,
};
