//! The experiment harness: regenerates every table/figure of the paper.
//!
//! | id | regenerates | path |
//! |----|-------------|------|
//! | `fig1` | Figure 1 (averaging, no speed-up) | simulator |
//! | `fig2` | Figure 2 (delta merge, speed-up) | simulator |
//! | `fig3` | Figure 3 (async + geometric delays) | simulator |
//! | `fig4` | Figure 4 (cloud, up to 32 units) | cloud runtime |
//! | `abl_tau_*` | §3 remark (merge frequency) | simulator |
//! | `abl_delay_*` | §4 remark (delay sensitivity) | simulator |
//!
//! Each run produces a [`FigureReport`]: one `(wall, C)` series per `M`,
//! plus a speed-up table against the `M = 1` baseline — the paper's
//! implicit headline number.

mod report;

pub use report::{format_report, format_speedups};

use anyhow::Result;

use crate::cloud;
use crate::config::FigureConfig;
use crate::metrics::{speedup_table, FigureReport, SpeedupRow};
use crate::runtime::Engine;
use crate::schemes;

/// Run one figure preset end to end (dispatches to the simulator or the
/// cloud runtime depending on the preset).
pub fn run_figure(fig: &FigureConfig) -> Result<FigureReport> {
    fig.validate()?;
    let mut report = FigureReport::new(fig.id.clone(), fig.title.clone());
    report.param("scheme", fig.base.scheme.label());
    report.param("tau", fig.base.scheme.tau());
    report.param("seed", fig.base.seed);
    report.param("points_per_worker", fig.base.run.points_per_worker);

    if let Some(cloud_cfg) = &fig.cloud {
        report.param("runtime", "cloud");
        for &m in &fig.ms {
            let mut cfg = fig.base.clone();
            cfg.m = m;
            let outcome = cloud::run_cloud(&cfg, cloud_cfg)?;
            report.series.push(outcome.series);
        }
    } else {
        report.param("runtime", "simulator");
        // One engine across the whole sweep (reuses a compiled PJRT
        // engine; a no-op for the native engine).
        let mut engine = fig.base.engine.build()?;
        for &m in &fig.ms {
            let mut cfg = fig.base.clone();
            cfg.m = m;
            let outcome = schemes::run_with_engine(&cfg, engine.as_mut())?;
            report.series.push(outcome.series);
        }
    }
    Ok(report)
}

/// Run one figure on a caller-provided engine (simulator figures only).
pub fn run_figure_with_engine(
    fig: &FigureConfig,
    engine: &mut dyn Engine,
) -> Result<FigureReport> {
    fig.validate()?;
    let mut report = FigureReport::new(fig.id.clone(), fig.title.clone());
    report.param("scheme", fig.base.scheme.label());
    for &m in &fig.ms {
        let mut cfg = fig.base.clone();
        cfg.m = m;
        let outcome = schemes::run_with_engine(&cfg, engine)?;
        report.series.push(outcome.series);
    }
    Ok(report)
}

/// The paper's speed-up criterion: time for each curve to reach a
/// threshold between the `M = 1` start and end values.
///
/// `frac` interpolates the threshold: 0 = starting distortion (trivial),
/// 1 = the baseline's final distortion (strict). The default in reports is
/// 0.9 — "90% of the baseline's total improvement".
pub fn speedups_at(report: &FigureReport, frac: f64) -> (f64, Vec<SpeedupRow>) {
    let base = &report.series[0];
    let threshold =
        base.first_value() + (base.min_value() - base.first_value()) * frac;
    (threshold, speedup_table(&report.series, threshold))
}
