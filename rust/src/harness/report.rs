//! Plain-text rendering of figure reports — the “same rows/series the
//! paper reports”, printable from the CLI and recorded in EXPERIMENTS.md.

use std::fmt::Write as _;

use crate::metrics::{FigureReport, SpeedupRow};

/// Render a report: per-series start/end/min values plus a coarse
//  ASCII sparkline of each curve over wall time.
pub fn format_report(report: &FigureReport) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "== {} — {}", report.id, report.title);
    for (k, v) in &report.params {
        let _ = writeln!(out, "   {k} = {v}");
    }
    let _ = writeln!(
        out,
        "{:>8} | {:>12} | {:>12} | {:>12} | {:>10} | {}",
        "series", "C(start)", "C(end)", "C(min)", "wall(s)", "curve"
    );
    for s in &report.series {
        let _ = writeln!(
            out,
            "{:>8} | {:>12.6} | {:>12.6} | {:>12.6} | {:>10.4} | {}",
            s.name,
            s.first_value(),
            s.last_value(),
            s.min_value(),
            s.last_wall(),
            sparkline(s, 40),
        );
    }
    out
}

/// Render the speed-up table (time to reach `threshold`).
pub fn format_speedups(threshold: f64, rows: &[SpeedupRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "time to C <= {threshold:.6}:");
    for r in rows {
        let t = r
            .time_to_threshold
            .map(|t| format!("{t:.4} s"))
            .unwrap_or_else(|| "never".into());
        let s = r
            .speedup
            .map(|s| format!("{s:.2}x"))
            .unwrap_or_else(|| "-".into());
        let _ = writeln!(out, "{:>8} | {:>12} | speed-up {:>8}", r.name, t, s);
    }
    out
}

/// Downsample a curve to `width` buckets and map values to eight shades.
fn sparkline(series: &crate::metrics::Series, width: usize) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if series.samples.is_empty() {
        return String::new();
    }
    let lo = series.min_value();
    let hi = series
        .samples
        .iter()
        .map(|s| s.value)
        .fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let t0 = series.samples[0].wall;
    let t1 = series.last_wall().max(t0 + 1e-12);
    (0..width)
        .map(|i| {
            let t = t0 + (t1 - t0) * (i as f64 + 0.5) / width as f64;
            let v = series.value_at(t);
            let idx = (((v - lo) / span) * 7.0).round().clamp(0.0, 7.0) as usize;
            BARS[idx]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Series;

    #[test]
    fn report_renders_all_series() {
        let mut r = FigureReport::new("figX", "test figure");
        for m in [1, 2] {
            let mut s = Series::new(format!("M={m}"));
            s.push(0.0, 1.0);
            s.push(1.0, 0.5 / m as f64);
            r.series.push(s);
        }
        let text = format_report(&r);
        assert!(text.contains("M=1"));
        assert!(text.contains("M=2"));
        assert!(text.contains("figX"));
    }

    #[test]
    fn speedup_table_renders() {
        let rows = vec![
            SpeedupRow { name: "M=1".into(), time_to_threshold: Some(2.0), speedup: Some(1.0) },
            SpeedupRow { name: "M=10".into(), time_to_threshold: None, speedup: None },
        ];
        let text = format_speedups(0.5, &rows);
        assert!(text.contains("never"));
        assert!(text.contains("1.00x"));
    }
}
