//! Minimal benchmarking kit (the offline build carries no criterion).
//!
//! Auto-calibrated timing loops: each benchmark is warmed up, then run for
//! a target wall budget; we report min / median / mean per iteration and
//! derived throughput. Black-box via `std::hint::black_box`.

#![allow(dead_code)]

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_secs_f64() * 1e9
    }

    /// items/s given `items` processed per iteration.
    pub fn throughput(&self, items: u64) -> f64 {
        items as f64 / self.median.as_secs_f64()
    }
}

/// Run `f` repeatedly: ~0.3 s warmup, then ~1.2 s of timed batches.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Stats {
    // warmup + calibration: how many calls fit in ~30 ms?
    let cal_start = Instant::now();
    let mut cal_iters = 0u64;
    while cal_start.elapsed() < Duration::from_millis(300) {
        black_box(f());
        cal_iters += 1;
        if cal_iters > 10_000_000 {
            break;
        }
    }
    let per_call = cal_start.elapsed().as_secs_f64() / cal_iters as f64;
    // batches of ~20 ms, at least 1 call
    let batch = ((0.02 / per_call) as u64).max(1);
    let budget = Duration::from_millis(1200);
    let mut samples: Vec<Duration> = Vec::new();
    let run_start = Instant::now();
    let mut total_iters = 0u64;
    while run_start.elapsed() < budget || samples.len() < 5 {
        let t0 = Instant::now();
        for _ in 0..batch {
            black_box(f());
        }
        samples.push(t0.elapsed() / batch as u32);
        total_iters += batch;
        if samples.len() >= 200 {
            break;
        }
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let min = samples[0];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let stats =
        Stats { name: name.to_string(), iters: total_iters, mean, median, min };
    println!(
        "{:<44} {:>12} med {:>12} min   ({} iters)",
        stats.name,
        fmt_dur(stats.median),
        fmt_dur(stats.min),
        stats.iters
    );
    stats
}

/// Print a throughput line under a benchmark.
pub fn throughput(stats: &Stats, items: u64, unit: &str) {
    println!(
        "{:<44} {:>12.3} M{unit}/s",
        format!("  -> {}", stats.name),
        stats.throughput(items) / 1e6
    );
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_secs_f64() * 1e9;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
