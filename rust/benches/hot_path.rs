//! Hot-path microbenchmarks: the per-layer numbers behind EXPERIMENTS.md
//! §Perf.
//!
//! * native engine: `vq_chunk` (the L3 simulator's inner loop), distortion,
//!   k-means step, delta algebra, data generation;
//! * PJRT engine (when `artifacts/` exists): the same entry points through
//!   the AOT Pallas kernels, plus the scanned `multi_chunk` that amortizes
//!   dispatch.
//!
//! ```bash
//! cargo bench --bench hot_path
//! ```

#[path = "kit/mod.rs"]
mod kit;

use dalvq::data::MixtureSpec;
use dalvq::runtime::{Engine, NativeEngine};
use dalvq::vq::{Codebook, Delta, Schedule};

fn main() {
    let kappa = 16;
    let dim = 16;
    let tau = 10;
    let spec = MixtureSpec::default();
    let points = spec.generate(1 << 14, 7, 0);
    let eval = spec.generate(1024, 7, 1);
    let w0 = Codebook::from_flat(kappa, dim, points[..kappa * dim].to_vec());
    let schedule = Schedule::paper_default();
    let mut eps = vec![0.0f32; tau];
    schedule.fill(0, &mut eps);

    kit::section("substrates");
    {
        let spec = spec.clone();
        kit::bench("mixture generate 10k points (d=16)", || {
            std::hint::black_box(spec.generate(10_000, 3, 2));
        });
    }
    {
        let mut d1 = Delta::zeros(kappa, dim);
        let d2 = Delta::from_flat(kappa, dim, points[..kappa * dim].to_vec());
        kit::bench("delta accumulate (16x16)", || d1.accumulate(&d2));
    }

    kit::section("native engine (L3 simulator inner loop)");
    let mut native = NativeEngine::new();
    {
        let mut w = w0.clone();
        let mut delta = Delta::zeros(kappa, dim);
        let chunk = &points[..tau * dim];
        let s = kit::bench("native vq_chunk tau=10 (k16,d16)", || {
            delta.clear();
            native.vq_chunk(&mut w, chunk, &eps, &mut delta).unwrap();
        });
        kit::throughput(&s, tau as u64, "pts");
    }
    {
        let s = kit::bench("native distortion 1024 pts (k16,d16)", || {
            std::hint::black_box(native.distortion_sum(&w0, &eval).unwrap());
        });
        kit::throughput(&s, 1024, "pts");
    }
    {
        let mut w = w0.clone();
        let s = kit::bench("native kmeans_step 1024 pts (k16,d16)", || {
            native.kmeans_step(&mut w, &eval).unwrap();
        });
        kit::throughput(&s, 1024, "pts");
    }

    pjrt_benches(&w0, &points, &eval, &schedule, &eps, tau, dim);
}

/// The PJRT half: only in `--features pjrt` builds with `artifacts/` present.
#[cfg(feature = "pjrt")]
fn pjrt_benches(
    w0: &Codebook,
    points: &[f32],
    eval: &[f32],
    schedule: &Schedule,
    eps: &[f32],
    tau: usize,
    dim: usize,
) {
    use dalvq::runtime::PjrtEngine;

    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("\n(artifacts/ missing — skipping PJRT benches; run `make artifacts`)");
        return;
    }

    kit::section("pjrt engine (AOT Pallas artifacts)");
    let kappa = w0.kappa();
    let mut pjrt = PjrtEngine::load(artifacts, "k16d16").expect("loading artifacts");
    {
        let mut w = w0.clone();
        let mut delta = Delta::zeros(kappa, dim);
        let chunk = &points[..tau * dim];
        let s = kit::bench("pjrt vq_chunk tau=10 (k16,d16)", || {
            delta.clear();
            pjrt.vq_chunk(&mut w, chunk, eps, &mut delta).unwrap();
        });
        kit::throughput(&s, tau as u64, "pts");
    }
    {
        let scan = pjrt.params().scan_chunks;
        let steps = scan * tau;
        let chunks = &points[..steps * dim];
        let mut eps_all = vec![0.0f32; steps];
        schedule.fill(0, &mut eps_all);
        let mut w = w0.clone();
        let mut delta = Delta::zeros(kappa, dim);
        let s = kit::bench(
            "pjrt multi_chunk S=16 (160 pts, one dispatch)",
            || {
                delta.clear();
                pjrt.multi_chunk(&mut w, chunks, &eps_all, &mut delta).unwrap();
            },
        );
        kit::throughput(&s, steps as u64, "pts");
    }
    {
        let s = kit::bench("pjrt distortion 1024 pts (k16,d16)", || {
            std::hint::black_box(pjrt.distortion_sum(w0, eval).unwrap());
        });
        kit::throughput(&s, 1024, "pts");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_benches(
    _w0: &Codebook,
    _points: &[f32],
    _eval: &[f32],
    _schedule: &Schedule,
    _eps: &[f32],
    _tau: usize,
    _dim: usize,
) {
    println!("\n(built without the `pjrt` feature — native benches only)");
}
