//! Figure-4 benchmark: the cloud runtime scale-up, `M` from 1 to 32 real
//! worker threads against latency-injected storage services.
//!
//! ```bash
//! cargo bench --bench cloud
//! ```
//!
//! Scaled to 30k points/worker so the sweep finishes in ~10 s of real time
//! (the series are real wall-clock measurements, not virtual time).

#[path = "kit/mod.rs"]
mod kit;

use std::time::Instant;

use dalvq::cloud::run_cloud;
use dalvq::config::presets;
use dalvq::metrics::{speedup_table, Series};

fn main() {
    let mut fig = presets::fig4();
    fig.base.run.points_per_worker = 30_000;
    let cloud = fig.cloud.clone().unwrap();

    kit::section(&format!("{} — {}", fig.id, fig.title));
    println!(
        "service latency {:.2} ms ±{:.0}%, pacing {:.0} µs/pt, exchange \
         window {} pts",
        cloud.service_latency * 1e3,
        cloud.latency_jitter * 100.0,
        cloud.point_compute * 1e6,
        cloud.points_per_exchange,
    );

    let mut series_all: Vec<Series> = Vec::new();
    println!(
        "{:>4} | {:>10} | {:>10} | {:>8} | {:>9} | {:>10}",
        "M", "C(start)", "C(end)", "merges", "wall (s)", "real run"
    );
    for &m in &fig.ms {
        let mut cfg = fig.base.clone();
        cfg.m = m;
        let t0 = Instant::now();
        let out = run_cloud(&cfg, &cloud).expect("cloud run");
        println!(
            "{:>4} | {:>10.5} | {:>10.5} | {:>8} | {:>9.3} | {:>10}",
            m,
            out.series.first_value(),
            out.series.last_value(),
            out.merges,
            out.series.last_wall(),
            kit::fmt_dur(t0.elapsed()),
        );
        series_all.push(out.series);
    }

    // speed-up table at 90% of the M=1 improvement
    let base = &series_all[0];
    let threshold =
        base.first_value() + (base.min_value() - base.first_value()) * 0.9;
    println!();
    for row in speedup_table(&series_all, threshold) {
        println!(
            "{:>6}: time-to-threshold {:>10}  scale-up {:>8}",
            row.name,
            row.time_to_threshold
                .map(|t| format!("{t:.3} s"))
                .unwrap_or_else(|| "never".into()),
            row.speedup
                .map(|s| format!("{s:.2}x"))
                .unwrap_or_else(|| "-".into()),
        );
    }
}
