//! Serving-path benchmark: an in-process `dalvq serve` stack under the
//! load generator — connection/workload sweep on the single-shard preset,
//! then the sharded-routing sweep (`S ∈ {1, 2, 4}`) under a fixed mixed
//! ingest/query load, recording latency percentiles per shard count.
//!
//! ```bash
//! cargo bench --bench serve
//! ```

#[path = "kit/mod.rs"]
mod kit;

use std::sync::Arc;

use dalvq::config::presets;
use dalvq::serve::{run_load, LoadSpec, Server, VqService};

fn main() {
    let p = presets::serve();
    kit::section("dalvq serve — in-process stack, native engine");
    println!(
        "fleet: M={} kappa={} dim={} | exchange window {} pts | pacing {:.1} us/pt",
        p.base.m,
        p.base.vq.kappa,
        p.base.dim(),
        p.serve.points_per_exchange,
        p.serve.point_compute * 1e6,
    );

    let service = Arc::new(VqService::start(&p.base, &p.serve).expect("service"));
    let server =
        Server::start(Arc::clone(&service), &p.serve.addr).expect("server");
    let addr = server.local_addr().to_string();
    println!("listening on {addr}\n");

    println!(
        "{:>6} {:>7} {:>11} {:>12} {:>9} {:>9} {:>9}",
        "conns", "ingest", "req/s", "pts/s", "p50", "p95", "p99"
    );
    for (connections, ingest_frac) in
        [(1, 0.0), (4, 0.0), (8, 0.0), (8, 0.25), (16, 0.25), (16, 1.0)]
    {
        let spec = LoadSpec {
            connections,
            requests_per_conn: 400,
            batch_points: 64,
            ingest_frac,
            seed: p.base.seed,
        };
        let report = run_load(&addr, &spec, &p.base.data.mixture).expect("load");
        println!(
            "{:>6} {:>6.0}% {:>11.0} {:>12.0} {:>6.0} us {:>6.0} us {:>6.0} us",
            connections,
            ingest_frac * 100.0,
            report.throughput_rps,
            report.points_per_sec,
            report.p50_us,
            report.p95_us,
            report.p99_us,
        );
    }

    server.shutdown().expect("server shutdown");
    let out = service.shutdown().expect("service shutdown");
    println!(
        "\nfleet during the bench: {} folds merged, {} points trained",
        out.merges,
        out.workers.iter().map(|w| w.points_trained).sum::<u64>(),
    );

    // ------------------------------------------------- sharded routing
    // Same mixed ingest/query load against S ∈ {1, 2, 4} codebook shards:
    // the quantity the ROADMAP tracks is p99 under mixed load as the
    // per-query scan shrinks from kappa*dim to probe_n * kappa/S * dim
    // while S independent fleets keep training.
    kit::section("sharded codebook routing — p99 across S (mixed load)");
    println!(
        "{:>6} {:>6} {:>11} {:>9} {:>9} {:>9} {:>8}",
        "S", "probe", "req/s", "p50", "p95", "p99", "merges"
    );
    for shards in [1usize, 2, 4] {
        let p = presets::serve_sharded(shards);
        let service =
            Arc::new(VqService::start(&p.base, &p.serve).expect("service"));
        let server =
            Server::start(Arc::clone(&service), &p.serve.addr).expect("server");
        let addr = server.local_addr().to_string();
        let spec = LoadSpec {
            connections: 8,
            requests_per_conn: 400,
            batch_points: 64,
            ingest_frac: 0.25,
            seed: p.base.seed,
        };
        let report = run_load(&addr, &spec, &p.base.data.mixture).expect("load");
        server.shutdown().expect("server shutdown");
        let out = service.shutdown().expect("service shutdown");
        println!(
            "{:>6} {:>6} {:>11.0} {:>6.0} us {:>6.0} us {:>6.0} us {:>8}",
            shards,
            p.serve.probe_n,
            report.throughput_rps,
            report.p50_us,
            report.p95_us,
            report.p99_us,
            out.merges,
        );
    }
}
