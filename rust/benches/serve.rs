//! Serving-path benchmark: an in-process `dalvq serve` stack under the
//! load generator — connection/workload sweep on the single-shard preset,
//! the sharded-routing sweep (`S ∈ {1, 2, 4}`) and the worker-count sweep
//! (`M ∈ {1, 2, 4, 8}`) under a fixed mixed ingest/query load, and the
//! durability comparison: time-to-first-trained-snapshot from a cold
//! start vs a warm restart out of a `--state-dir` checkpoint, plus the
//! rebalance sweep — ingest imbalance before/after one online epoch swap
//! under a zipf-skewed write-heavy load, and the swap's wall cost.
//!
//! ```bash
//! cargo bench --bench serve
//! ```

#[path = "kit/mod.rs"]
mod kit;

use std::alloc::{GlobalAlloc, Layout, System};
use std::hint::black_box;
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use dalvq::config::presets;
use dalvq::data::MixtureSpec;
use dalvq::runtime::{Engine, NativeEngine};
use dalvq::serve::protocol::{
    begin_frame, end_frame, read_frame_into, write_frame, Decoder, Request,
    RequestRef, Response,
};
use dalvq::serve::{max_over_mean, run_load, LoadSpec, Server, VqService};
use dalvq::vq::{nearest_batch, nearest_with_dist, Codebook};

// The whole bench binary runs under a counting allocator: one relaxed
// counter bump per alloc/realloc, the same overhead on both sides of the
// wire A/B below, and it lets the decode probe *measure* the zero-copy
// claim (allocations per parsed frame) instead of asserting it in prose.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() {
    // CI runs only the wire A/B (it has a regression gate on the
    // artifact); the sweeps above it are by-hand benches.
    if std::env::var_os("DALVQ_BENCH_WIRE_ONLY").is_some() {
        wire_bench();
        return;
    }
    let p = presets::serve();
    kit::section("dalvq serve — in-process stack, native engine");
    println!(
        "fleet: M={} kappa={} dim={} | exchange window {} pts | pacing {:.1} us/pt",
        p.base.m,
        p.base.vq.kappa,
        p.base.dim(),
        p.serve.points_per_exchange,
        p.serve.point_compute * 1e6,
    );

    let service = VqService::start(&p.base, &p.serve).expect("service");
    let server =
        Server::start(Arc::clone(&service), &p.serve.addr).expect("server");
    let addr = server.local_addr().to_string();
    println!("listening on {addr}\n");

    println!(
        "{:>6} {:>7} {:>11} {:>12} {:>9} {:>9} {:>9}",
        "conns", "ingest", "req/s", "pts/s", "p50", "p95", "p99"
    );
    for (connections, ingest_frac) in
        [(1, 0.0), (4, 0.0), (8, 0.0), (8, 0.25), (16, 0.25), (16, 1.0)]
    {
        let spec = LoadSpec {
            connections,
            requests_per_conn: 400,
            batch_points: 64,
            pipeline: 1,
            ingest_frac,
            skew: 0.0,
            read_only: false,
            trace: false,
            seed: p.base.seed,
        };
        let report = run_load(&addr, &spec, &p.base.data.mixture).expect("load");
        println!(
            "{:>6} {:>6.0}% {:>11.0} {:>12.0} {:>6.0} us {:>6.0} us {:>6.0} us",
            connections,
            ingest_frac * 100.0,
            report.throughput_rps,
            report.points_per_sec,
            report.p50_us,
            report.p95_us,
            report.p99_us,
        );
    }

    server.shutdown().expect("server shutdown");
    let out = service.shutdown().expect("service shutdown");
    println!(
        "\nfleet during the bench: {} folds merged, {} points trained",
        out.merges,
        out.workers.iter().map(|w| w.points_trained).sum::<u64>(),
    );

    // ------------------------------------------------- sharded routing
    // Same mixed ingest/query load against S ∈ {1, 2, 4} codebook shards:
    // the quantity the ROADMAP tracks is p99 under mixed load as the
    // per-query scan shrinks from kappa*dim to probe_n * kappa/S * dim
    // while S independent fleets keep training.
    kit::section("sharded codebook routing — p99 across S (mixed load)");
    println!(
        "{:>6} {:>6} {:>11} {:>9} {:>9} {:>9} {:>8}",
        "S", "probe", "req/s", "p50", "p95", "p99", "merges"
    );
    for shards in [1usize, 2, 4] {
        let p = presets::serve_sharded(shards);
        let (report, merges) = mixed_load_sweep(&p);
        println!(
            "{:>6} {:>6} {:>11.0} {:>6.0} us {:>6.0} us {:>6.0} us {:>8}",
            shards,
            p.serve.probe_n,
            report.throughput_rps,
            report.p50_us,
            report.p95_us,
            report.p99_us,
            merges,
        );
    }

    // ------------------------------------------------- worker-count sweep
    // The still-open ROADMAP axis: p99 under mixed load as the training
    // fleet grows. More workers fold more deltas behind the same read
    // path (each exchange is kappa*dim floats through the shard queue),
    // so this measures how much write-side concurrency the epoch-swapped
    // snapshot design absorbs before the tail feels it.
    kit::section("worker-count sweep — p99 across M (mixed load, S = 1)");
    println!(
        "{:>6} {:>11} {:>9} {:>9} {:>9} {:>8}",
        "M", "req/s", "p50", "p95", "p99", "merges"
    );
    for m in [1usize, 2, 4, 8] {
        let mut p = presets::serve();
        p.base.m = m;
        let (report, merges) = mixed_load_sweep(&p);
        println!(
            "{:>6} {:>11.0} {:>6.0} us {:>6.0} us {:>6.0} us {:>8}",
            m,
            report.throughput_rps,
            report.p50_us,
            report.p95_us,
            report.p99_us,
            merges,
        );
    }

    // -------------------------------------- cold start vs warm restart
    // The durability subsystem's headline number: how long until the
    // service answers from a *trained* snapshot (version >= TARGET
    // folds). Cold starts must train their way there; a warm restart
    // reads it off disk and serves it before the first new fold lands.
    kit::section("durable state — time to first trained snapshot");
    const TARGET_FOLDS: u64 = 32;
    let dir = std::env::temp_dir()
        .join(format!("dalvq-bench-state-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut p = presets::serve_durable(&dir);
    p.serve.checkpoint_every = 8;

    let cold_start = Instant::now();
    let service = VqService::start(&p.base, &p.serve).expect("cold service");
    wait_for_version(&service, TARGET_FOLDS);
    let cold_ms = cold_start.elapsed().as_secs_f64() * 1e3;
    service.checkpoint_now().expect("checkpoint");
    service.shutdown().expect("cold shutdown");
    println!(
        "cold start:   {cold_ms:>8.1} ms to a version-{TARGET_FOLDS} snapshot \
         (trained from scratch)"
    );

    let warm_start = Instant::now();
    let service = VqService::start(&p.base, &p.serve).expect("warm service");
    wait_for_version(&service, TARGET_FOLDS);
    let warm_ms = warm_start.elapsed().as_secs_f64() * 1e3;
    let resumed = service.shard_versions();
    service.shutdown().expect("warm shutdown");
    println!(
        "warm restart: {warm_ms:>8.1} ms to the same snapshot (resumed at \
         versions {resumed:?} from {})",
        dir.display(),
    );
    let _ = std::fs::remove_dir_all(&dir);

    // ------------------------------------------------- rebalance sweep
    // The live-rebalancing subsystem's headline numbers: how skewed the
    // frozen partition gets under a zipf-hot write-heavy load, what one
    // online epoch swap costs (quiesce -> checkpoint -> ingest-weighted
    // retrain -> row migration -> fleet respawn), and where per-shard
    // ingest imbalance lands once the new partition serves the same load.
    kit::section("live shard rebalancing — S = 4, zipf-2 write-heavy load");
    let dir = std::env::temp_dir()
        .join(format!("dalvq-bench-rebalance-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let p = presets::serve_rebalancing(4, &dir, 0.0); // manual trigger
    let service = VqService::start(&p.base, &p.serve).expect("service");
    let server =
        Server::start(Arc::clone(&service), &p.serve.addr).expect("server");
    let addr = server.local_addr().to_string();
    let spec = LoadSpec {
        connections: 8,
        requests_per_conn: 200,
        batch_points: 64,
        pipeline: 1,
        ingest_frac: 0.8,
        skew: 2.0,
        read_only: false,
        trace: false,
        seed: p.base.seed,
    };
    run_load(&addr, &spec, &p.base.data.mixture).expect("skewed load");
    let before = service.stats();
    let swap_start = Instant::now();
    let out = service.rebalance().expect("rebalance");
    let swap_ms = swap_start.elapsed().as_secs_f64() * 1e3;
    run_load(&addr, &spec, &p.base.data.mixture).expect("post-swap load");
    let after = service.stats();
    println!(
        "frozen epoch 0:  max/mean ingest {:>5.2} over {:>7} pts  {:?}",
        max_over_mean(&before.shard_ingest),
        before.shard_ingest.iter().sum::<u64>(),
        before.shard_ingest,
    );
    println!(
        "epoch swap:      {swap_ms:>7.1} ms ({} prototype rows migrated, \
         router v{})",
        out.moved_rows, out.router_version,
    );
    println!(
        "rebalanced v{}:  max/mean ingest {:>5.2} over {:>7} pts  {:?}",
        after.router_version,
        max_over_mean(&after.shard_ingest),
        after.shard_ingest.iter().sum::<u64>(),
        after.shard_ingest,
    );
    server.shutdown().expect("server shutdown");
    service.shutdown().expect("service shutdown");
    let _ = std::fs::remove_dir_all(&dir);

    // --------------------------------------- checkpoint-shipped replicas
    // The replication subsystem's headline number: aggregate read
    // throughput of 1 leader + {0, 1, 3} read-only followers, each
    // endpoint driven by its own read-only load generator concurrently.
    // The leader keeps training throughout (followers re-sync every
    // 100 ms), so this measures the scale-out under live replication,
    // not against a frozen codebook.
    kit::section("read replicas — aggregate read throughput (read-only load)");
    let dir = std::env::temp_dir()
        .join(format!("dalvq-bench-replicas-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut p = presets::serve_durable(&dir);
    p.serve.checkpoint_every = 8;
    let leader = VqService::start(&p.base, &p.serve).expect("leader");
    let lsrv = Server::start(Arc::clone(&leader), &p.serve.addr).expect("server");
    let laddr = lsrv.local_addr().to_string();
    println!(
        "{:>10} {:>10} {:>13} {:>12} {:>10}",
        "followers", "endpoints", "agg req/s", "agg pts/s", "worst p99"
    );
    for followers in [0usize, 1, 3] {
        let mut stacks = Vec::with_capacity(followers);
        let mut endpoints = vec![laddr.clone()];
        for _ in 0..followers {
            let mut fp = presets::serve_follower(laddr.as_str());
            fp.serve.sync_every_ms = 100;
            let fsvc = VqService::start(&fp.base, &fp.serve).expect("follower");
            let fsrv =
                Server::start(Arc::clone(&fsvc), &fp.serve.addr).expect("fsrv");
            endpoints.push(fsrv.local_addr().to_string());
            stacks.push((fsvc, fsrv));
        }
        // one read-only generator per endpoint, all running concurrently
        let spec = LoadSpec {
            connections: 4,
            requests_per_conn: 300,
            batch_points: 64,
            pipeline: 1,
            ingest_frac: 0.0,
            skew: 0.0,
            read_only: true,
            trace: false,
            seed: p.base.seed,
        };
        let mixture = p.base.data.mixture.clone();
        let joins: Vec<_> = endpoints
            .iter()
            .map(|addr| {
                let addr = addr.clone();
                let spec = spec.clone();
                let mixture = mixture.clone();
                std::thread::spawn(move || run_load(&addr, &spec, &mixture))
            })
            .collect();
        let reports: Vec<_> = joins
            .into_iter()
            .map(|j| j.join().expect("load thread").expect("replica load"))
            .collect();
        let agg_rps: f64 = reports.iter().map(|r| r.throughput_rps).sum();
        let agg_pts: f64 = reports.iter().map(|r| r.points_per_sec).sum();
        let worst_p99 = reports.iter().map(|r| r.p99_us).fold(0.0, f64::max);
        println!(
            "{:>10} {:>10} {:>13.0} {:>12.0} {:>7.0} us",
            followers,
            endpoints.len(),
            agg_rps,
            agg_pts,
            worst_p99,
        );
        for (fsvc, fsrv) in stacks {
            fsrv.shutdown().expect("fsrv shutdown");
            fsvc.shutdown().expect("follower shutdown");
        }
    }
    lsrv.shutdown().expect("server shutdown");
    leader.shutdown().expect("leader shutdown");
    let _ = std::fs::remove_dir_all(&dir);

    // ------------------------------------------------ batched query plane
    // Three layers of the read path, measured where each one pays off:
    // the fused kernel against the scalar per-point scan at large
    // kappa*dim (one codebook sweep per batch vs one per point), the
    // engine backends behind `Engine::nearest_chunk` (PJRT loudly
    // skipped when absent, never silently), and the coalesced server
    // against the direct one under the same read-only load. The numbers
    // land in BENCH_query_plane.json.
    kit::section("batched query plane — fused kernel vs scalar scan");
    let mut kernel_rows = Vec::new();
    for (kappa, dim) in [(256usize, 16usize), (256, 64), (1024, 32)] {
        let n = 4_096usize;
        let spec = MixtureSpec {
            components: 16,
            dim,
            separation: 4.0,
            std: 0.5,
            imbalance: 0.3,
            noise_frac: 0.05,
        };
        let points = spec.generate(n, 42, 0);
        let w = Codebook::from_flat(kappa, dim, spec.generate(kappa, 42, 1));

        // The fused path must buy its speed without changing one bit.
        let (fused_codes, fused_dists) = nearest_batch(&w, &points);
        for (i, z) in points.chunks_exact(dim).enumerate() {
            let (code, d) = nearest_with_dist(&w, z);
            assert_eq!(fused_codes[i] as usize, code, "code {i} diverged");
            assert_eq!(fused_dists[i].to_bits(), d.to_bits(), "dist {i}");
        }

        let scalar = kit::bench(&format!("scalar scan k{kappa} d{dim}"), || {
            let mut acc = 0u64;
            for z in points.chunks_exact(dim) {
                let (code, d) = nearest_with_dist(&w, z);
                acc = acc.wrapping_add(code as u64 ^ d.to_bits() as u64);
            }
            black_box(acc);
        });
        let fused = kit::bench(&format!("fused scan  k{kappa} d{dim}"), || {
            black_box(nearest_batch(&w, &points));
        });
        let speedup =
            scalar.median.as_secs_f64() / fused.median.as_secs_f64();
        println!("  -> {n} points, fused speedup {speedup:.2}x");
        kernel_rows.push((kappa, dim, n, scalar, fused, speedup));
    }

    kit::section("engine nearest_chunk — native vs PJRT artifacts");
    let (kappa, dim, n) = (256usize, 32usize, 8_192usize);
    let spec = MixtureSpec {
        components: 16,
        dim,
        separation: 4.0,
        std: 0.5,
        imbalance: 0.3,
        noise_frac: 0.05,
    };
    let points = spec.generate(n, 42, 0);
    let w = Codebook::from_flat(kappa, dim, spec.generate(kappa, 42, 1));
    let mut native_engine = NativeEngine::new();
    let native = kit::bench(&format!("native nearest_chunk k{kappa} d{dim}"), || {
        black_box(
            native_engine.nearest_chunk(&w, &points).expect("native scan"),
        );
    });
    kit::throughput(&native, n as u64, "pts");
    let (pjrt_ns, pjrt_note) = pjrt_nearest_bench();

    kit::section("coalesced serving — direct vs --batch-window-us");
    println!(
        "{:>8} {:>7} {:>11} {:>9} {:>9} {:>9}",
        "mode", "window", "req/s", "p50", "p95", "p99"
    );
    let mut serve_rows = Vec::new();
    for (mode, window_us) in [("direct", 0u64), ("batched", 200)] {
        let mut p = presets::serve_sharded(4);
        p.serve.batch_window_us = window_us;
        let service = VqService::start(&p.base, &p.serve).expect("service");
        let server =
            Server::start(Arc::clone(&service), &p.serve.addr).expect("server");
        let addr = server.local_addr().to_string();
        // Many connections issuing small read batches: the regime where
        // cross-request coalescing has requests to merge.
        let spec = LoadSpec {
            connections: 16,
            requests_per_conn: 300,
            batch_points: 16,
            pipeline: 1,
            ingest_frac: 0.0,
            skew: 0.0,
            read_only: true,
            trace: false,
            seed: p.base.seed,
        };
        let report = run_load(&addr, &spec, &p.base.data.mixture).expect("load");
        println!(
            "{:>8} {:>4} us {:>11.0} {:>6.0} us {:>6.0} us {:>6.0} us",
            mode,
            window_us,
            report.throughput_rps,
            report.p50_us,
            report.p95_us,
            report.p99_us,
        );
        server.shutdown().expect("server shutdown");
        service.shutdown().expect("service shutdown");
        serve_rows.push((mode, window_us, report));
    }

    // ---------------------------------------------------- JSON artifact
    let mut json = String::from("{\n  \"bench\": \"query_plane\",\n");
    json.push_str("  \"kernel\": [\n");
    for (i, (kappa, dim, n, scalar, fused, speedup)) in
        kernel_rows.iter().enumerate()
    {
        json.push_str(&format!(
            "    {{\"kappa\": {kappa}, \"dim\": {dim}, \"points\": {n}, \
             \"scalar_ns\": {:.0}, \"fused_ns\": {:.0}, \
             \"speedup\": {speedup:.3}}}{}\n",
            scalar.median.as_secs_f64() * 1e9,
            fused.median.as_secs_f64() * 1e9,
            if i + 1 < kernel_rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"engine\": {{\"kappa\": {kappa}, \"dim\": {dim}, \"points\": {n}, \
         \"native_ns\": {:.0}, \"pjrt_ns\": {}, \"pjrt_note\": {:?}}},\n",
        native.median.as_secs_f64() * 1e9,
        match pjrt_ns {
            Some(ns) => format!("{ns:.0}"),
            None => "null".into(),
        },
        pjrt_note,
    ));
    json.push_str("  \"serve\": [\n");
    for (i, (mode, window_us, report)) in serve_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"mode\": {mode:?}, \"window_us\": {window_us}, \
             \"rps\": {:.1}, \"p50_us\": {:.1}, \"p95_us\": {:.1}, \
             \"p99_us\": {:.1}}}{}\n",
            report.throughput_rps,
            report.p50_us,
            report.p95_us,
            report.p99_us,
            if i + 1 < serve_rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_query_plane.json", &json)
        .expect("writing BENCH_query_plane.json");
    println!("\nwrote BENCH_query_plane.json");

    wire_bench();
}

/// A/B of the server core this PR replaced: a thread-per-connection
/// blocking server (rebuilt in miniature below — one OS thread per
/// conn, one heap frame per request and reply, throwaway-connection
/// shutdown) against the event-loop [`Server`], same service, same
/// 32-connection mixed load. CI gates on the artifact: event-loop p99
/// no worse than the baseline, and zero allocations per frame in the
/// steady-state decode loop.
fn wire_bench() {
    kit::section("wire path — thread-per-conn baseline vs event loop");

    let (frames_parsed, decode_allocs) = decode_alloc_probe();
    let allocs_per_frame = decode_allocs as f64 / frames_parsed as f64;
    println!(
        "steady-state decode: {frames_parsed} frames, {decode_allocs} \
         allocations ({allocs_per_frame:.3} per frame)"
    );

    let p = presets::serve();
    let wire_spec = LoadSpec {
        connections: 32,
        requests_per_conn: 400,
        batch_points: 64,
        pipeline: 1,
        ingest_frac: 0.25,
        skew: 0.0,
        read_only: false,
        trace: false,
        seed: p.base.seed,
    };
    println!(
        "\n{:>16} {:>11} {:>9} {:>9} {:>9}",
        "server", "req/s", "p50", "p95", "p99"
    );

    let service = VqService::start(&p.base, &p.serve).expect("service");
    let baseline = BaselineServer::start(Arc::clone(&service));
    let base_report = run_load(baseline.addr(), &wire_spec, &p.base.data.mixture)
        .expect("baseline load");
    baseline.shutdown();
    print_wire_row("thread/conn", &base_report);

    let server =
        Server::start(Arc::clone(&service), &p.serve.addr).expect("server");
    let addr = server.local_addr().to_string();
    let ev_report =
        run_load(&addr, &wire_spec, &p.base.data.mixture).expect("event load");
    print_wire_row("event loop", &ev_report);

    // The same load with eight requests in flight per connection — the
    // regime the blocking baseline cannot express at all (it reads one
    // frame, answers, reads the next). Recorded, not gated.
    let mut piped_spec = wire_spec.clone();
    piped_spec.pipeline = 8;
    let piped_report = run_load(&addr, &piped_spec, &p.base.data.mixture)
        .expect("pipelined load");
    print_wire_row("event loop x8", &piped_report);

    server.shutdown().expect("server shutdown");
    service.shutdown().expect("service shutdown");

    let json = format!(
        "{{\n  \"bench\": \"wire\",\n  \"connections\": {},\n  \
         \"requests_per_conn\": {},\n  \"batch_points\": {},\n  \
         \"decode\": {{\"frames\": {frames_parsed}, \"allocs\": \
         {decode_allocs}, \"allocs_per_frame\": {allocs_per_frame:.4}}},\n  \
         \"baseline\": {},\n  \"eventloop\": {},\n  \
         \"eventloop_pipelined\": {}\n}}\n",
        wire_spec.connections,
        wire_spec.requests_per_conn,
        wire_spec.batch_points,
        wire_row_json(1, &base_report),
        wire_row_json(1, &ev_report),
        wire_row_json(piped_spec.pipeline, &piped_report),
    );
    std::fs::write("BENCH_wire.json", &json).expect("writing BENCH_wire.json");
    println!("\nwrote BENCH_wire.json");
}

/// One aligned row of the wire A/B table.
fn print_wire_row(name: &str, report: &dalvq::serve::LoadReport) {
    println!(
        "{:>16} {:>11.0} {:>6.0} us {:>6.0} us {:>6.0} us",
        name,
        report.throughput_rps,
        report.p50_us,
        report.p95_us,
        report.p99_us,
    );
}

/// One server's slice of the `BENCH_wire.json` artifact.
fn wire_row_json(pipeline: usize, report: &dalvq::serve::LoadReport) -> String {
    format!(
        "{{\"pipeline\": {pipeline}, \"rps\": {:.1}, \"p50_us\": {:.1}, \
         \"p95_us\": {:.1}, \"p99_us\": {:.1}}}",
        report.throughput_rps, report.p50_us, report.p95_us, report.p99_us,
    )
}

/// Build a realistic request stream (64-point read and ingest payloads),
/// then parse it twice through one [`Decoder`] in socket-sized chunks.
/// Pass one warms the ring (growth allocates); pass two is the steady
/// state the server lives in, and its allocation delta divided by frames
/// parsed is the number CI gates at zero. `(frames, allocations)`.
fn decode_alloc_probe() -> (u64, u64) {
    let points: Vec<f32> =
        (0..64 * 8).map(|i| i as f32 * 0.25 - 3.0).collect();
    let reqs = [
        Request::Encode { points: points.clone() },
        Request::Nearest { points: points.clone() },
        Request::Distortion { points: points.clone() },
        Request::Ingest { points: points.clone() },
        Request::Stats,
    ];
    let mut stream = Vec::new();
    const FRAMES: usize = 256;
    for i in 0..FRAMES {
        let at = begin_frame(&mut stream);
        reqs[i % reqs.len()].encode_into(&mut stream);
        end_frame(&mut stream, at).expect("frame under cap");
    }

    let mut dec = Decoder::new();
    let parse_pass = |dec: &mut Decoder| -> u64 {
        let mut parsed = 0;
        for chunk in stream.chunks(4096) {
            dec.spare(chunk.len())[..chunk.len()].copy_from_slice(chunk);
            dec.advance(chunk.len());
            while let Some(frame) = dec.next_frame().expect("well-formed") {
                black_box(RequestRef::decode(frame).expect("decodes"));
                parsed += 1;
            }
        }
        parsed
    };
    let warm = parse_pass(&mut dec);
    assert_eq!(warm, FRAMES as u64, "warm pass must drain every frame");
    let before = ALLOCS.load(Ordering::Relaxed);
    let parsed = parse_pass(&mut dec);
    let allocs = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(parsed, FRAMES as u64, "steady pass must drain every frame");
    (parsed, allocs)
}

/// The server design this PR retired, rebuilt in miniature as the A/B
/// baseline: a blocking accept loop, one OS thread per connection, a
/// heap-allocated frame per request and per reply — and shutdown via
/// the throwaway connection the event loop's wake token made obsolete.
struct BaselineServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl BaselineServer {
    fn start(service: Arc<VqService>) -> Self {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("local addr").to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = thread::spawn(move || {
            let mut conns = Vec::new();
            for stream in listener.incoming() {
                if stop_flag.load(Ordering::Acquire) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let svc = Arc::clone(&service);
                conns.push(thread::spawn(move || baseline_conn(&svc, stream)));
            }
            for c in conns {
                let _ = c.join();
            }
        });
        BaselineServer { addr, stop, handle: Some(handle) }
    }

    fn addr(&self) -> &str {
        &self.addr
    }

    fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn baseline_conn(service: &VqService, stream: TcpStream) {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = BufWriter::new(stream);
    let mut frame = Vec::new();
    loop {
        match read_frame_into(&mut reader, &mut frame) {
            Ok(true) => {}
            Ok(false) | Err(_) => return,
        }
        let reply = match Request::decode(&frame) {
            Ok(req) => baseline_dispatch(service, req),
            Err(e) => Response::Error { message: format!("{e:#}") },
        };
        if write_frame(&mut writer, &reply.encode()).is_err() {
            return;
        }
        if writer.flush().is_err() {
            return;
        }
    }
}

fn baseline_dispatch(service: &VqService, req: Request) -> Response {
    match req {
        Request::Encode { points } => {
            let (version, codes) = service.query_encode(&points);
            Response::Codes { version, codes }
        }
        Request::Nearest { points } => {
            let (version, indices, dists) = service.query_nearest(&points);
            Response::Neighbors { version, indices, dists }
        }
        Request::Distortion { points } => {
            let (version, value) = service.query_distortion(&points);
            Response::Distortion { version, value }
        }
        Request::Ingest { points } => match service.ingest(&points) {
            Ok((accepted, shed)) => Response::IngestAck { accepted, shed },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        _ => Response::Error {
            message: "baseline server answers query and ingest ops only"
                .into(),
        },
    }
}

/// The PJRT side of the `nearest_chunk` comparison: `(median ns, note)`.
/// Built without the `pjrt` feature — or with it but without lowered
/// artifacts — this skips LOUDLY, naming exactly what is missing, and
/// records the reason in the JSON artifact instead of a number.
#[cfg(not(feature = "pjrt"))]
fn pjrt_nearest_bench() -> (Option<f64>, String) {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join("manifest.json");
    let note = format!(
        "SKIPPED: built without the `pjrt` feature (artifacts expected at \
         {})",
        manifest.display()
    );
    println!("{note}");
    (None, note)
}

#[cfg(feature = "pjrt")]
fn pjrt_nearest_bench() -> (Option<f64>, String) {
    use dalvq::runtime::PjrtEngine;
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = dir.join("manifest.json");
    if !manifest.exists() {
        let note = format!(
            "SKIPPED: {} not found — run `make artifacts`",
            manifest.display()
        );
        println!("{note}");
        return (None, note);
    }
    let mut engine = match PjrtEngine::load(&dir, "k16d16") {
        Ok(e) => e,
        Err(e) => {
            let note = format!("SKIPPED: loading variant k16d16: {e:#}");
            println!("{note}");
            return (None, note);
        }
    };
    let p = engine.params().clone();
    let spec = MixtureSpec {
        components: 16,
        dim: p.dim,
        separation: 4.0,
        std: 0.5,
        imbalance: 0.3,
        noise_frac: 0.05,
    };
    let n = p.eval_batch * 3;
    let points = spec.generate(n, 42, 0);
    let w = Codebook::from_flat(p.kappa, p.dim, spec.generate(p.kappa, 42, 1));
    if let Err(e) = engine.nearest_chunk(&w, &points) {
        let note = format!(
            "SKIPPED: {e:#} (artifact predates the batched read path — \
             re-run `make artifacts`)"
        );
        println!("{note}");
        return (None, note);
    }
    let stats =
        kit::bench(&format!("pjrt nearest_chunk k{} d{}", p.kappa, p.dim), || {
            black_box(engine.nearest_chunk(&w, &points).expect("pjrt scan"));
        });
    kit::throughput(&stats, n as u64, "pts");
    (Some(stats.median.as_secs_f64() * 1e9), "ok".into())
}

/// Stand up the preset's stack, drive the standard mixed load (8 conns x
/// 400 reqs, 64 pts/batch, 25% ingest), tear it down. Both sweep loops
/// (S and M) share this so the load shape stays identical across axes.
fn mixed_load_sweep(p: &presets::ServePreset) -> (dalvq::serve::LoadReport, u64) {
    let service = VqService::start(&p.base, &p.serve).expect("service");
    let server =
        Server::start(Arc::clone(&service), &p.serve.addr).expect("server");
    let addr = server.local_addr().to_string();
    let spec = LoadSpec {
        connections: 8,
        requests_per_conn: 400,
        batch_points: 64,
        pipeline: 1,
        ingest_frac: 0.25,
        skew: 0.0,
        read_only: false,
        trace: false,
        seed: p.base.seed,
    };
    let report = run_load(&addr, &spec, &p.base.data.mixture).expect("load");
    server.shutdown().expect("server shutdown");
    let out = service.shutdown().expect("service shutdown");
    (report, out.merges)
}

/// Block until the service's summed snapshot version reaches `target`.
fn wait_for_version(service: &VqService, target: u64) {
    while service.version() < target {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}
