//! Figure benchmarks: regenerate the series behind paper Figures 1–3 and
//! the two DESIGN.md ablations, timing each harness run and printing the
//! same rows the paper reports (start/end distortion per `M`, time to
//! threshold, speed-up vs `M = 1`).
//!
//! ```bash
//! cargo bench --bench figures
//! ```
//!
//! Scaled to 50k points/worker (vs 200k in `dalvq figures`) so the whole
//! bench finishes in tens of seconds; the curve *shapes* are unchanged.

#[path = "kit/mod.rs"]
mod kit;

use std::time::Instant;

use dalvq::config::{presets, FigureConfig};
use dalvq::harness;

fn run_figure_bench(mut fig: FigureConfig, points: u64) {
    fig.base.run.points_per_worker = points;
    kit::section(&format!("{} — {}", fig.id, fig.title));
    let t0 = Instant::now();
    let report = harness::run_figure(&fig).expect("figure run");
    let elapsed = t0.elapsed();
    print!("{}", harness::format_report(&report));
    let (threshold, rows) = harness::speedups_at(&report, 0.9);
    print!("{}", harness::format_speedups(threshold, &rows));
    println!("harness wall time: {}", kit::fmt_dur(elapsed));
}

fn main() {
    // paper figures (simulator)
    run_figure_bench(presets::fig1(), 50_000);
    run_figure_bench(presets::fig2(), 50_000);
    run_figure_bench(presets::fig3(), 50_000);

    // DESIGN.md ablations
    for fig in presets::ablation_tau() {
        run_figure_bench(fig, 50_000);
    }
    for fig in presets::ablation_delay() {
        run_figure_bench(fig, 50_000);
    }
}
